"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting output shapes and absence of NaNs. Decode round-trips where
the arch supports it (prefill → decode consistency is covered separately in
test_cache_consistency.py).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED, get_config
from repro.configs.base import ShapeSpec
from repro.launch.specs import make_concrete_batch
from repro.launch.steps import make_serve_step, make_train_state, make_train_step
from repro.models.model import build_model

SMOKE_TRAIN = ShapeSpec("smoke_train", seq_len=64, global_batch=2, kind="train")
SMOKE_PREFILL = ShapeSpec("smoke_prefill", seq_len=64, global_batch=2, kind="prefill")
SMOKE_DECODE = ShapeSpec("smoke_decode", seq_len=64, global_batch=2, kind="decode")


def _finite(tree):
    return all(
        bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
        for x in jax.tree.leaves(tree)
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)
    )


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch, rng):
    cfg = get_config(arch).reduced()
    fns = build_model(cfg)
    params = fns.init(rng)
    batch = make_concrete_batch(cfg, SMOKE_TRAIN)
    loss, aux = jax.jit(fns.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss {loss}"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_one_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    state = make_train_state(cfg, rng)
    step = jax.jit(make_train_step(cfg, total_steps=100))
    batch = make_concrete_batch(cfg, SMOKE_TRAIN)
    new_state, metrics = step(state, batch)
    assert int(new_state["step"]) == 1
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: loss={metrics['loss']}"
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0.0
    assert _finite(new_state["params"]), f"{arch}: NaN in updated params"


@pytest.mark.parametrize(
    "arch", [a for a in sorted(ARCHS) if ARCHS[a].has_decode]
)
def test_prefill_then_decode(arch, rng):
    cfg = get_config(arch).reduced()
    fns = build_model(cfg)
    params = fns.init(rng)
    batch = make_concrete_batch(cfg, SMOKE_PREFILL)
    logits, cache = jax.jit(fns.prefill)(params, batch)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    serve = jax.jit(make_serve_step(cfg))
    dec_batch = {"tokens": jnp.ones((2, 1), jnp.int32)}
    logits2, cache2 = serve(params, cache, dec_batch)
    assert logits2.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))
    assert int(cache2["index"]) == int(cache["index"]) + 1


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_config_matches_assignment(arch):
    """The full (non-reduced) config fields match the assignment sheet."""
    cfg = get_config(arch)
    expected = {
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    }[arch]
    got = (
        cfg.num_layers,
        cfg.d_model,
        cfg.num_heads,
        cfg.num_kv_heads,
        cfg.moe.d_ff if cfg.moe is not None else cfg.d_ff,
        cfg.vocab_size,
    )
    assert got == expected, f"{arch}: {got} != {expected}"
    if arch == "grok-1-314b":
        assert (cfg.moe.num_experts, cfg.moe.top_k) == (8, 2)
    if arch == "arctic-480b":
        assert (cfg.moe.num_experts, cfg.moe.top_k) == (128, 2)
        assert cfg.moe.dense_residual
    if arch == "mamba2-1.3b":
        assert cfg.ssm.state_dim == 128
    if arch == "hubert-xlarge":
        assert not cfg.has_decode and not cfg.causal


def test_param_counts_in_band():
    """Analytic param counts land near the advertised sizes."""
    bands = {
        "grok-1-314b": (250e9, 380e9),
        "arctic-480b": (400e9, 560e9),
        "qwen1.5-110b": (90e9, 130e9),
        "llama3.2-3b": (2.5e9, 4.5e9),
        "mamba2-1.3b": (0.9e9, 1.8e9),
    }
    for arch, (lo, hi) in bands.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]B"
