"""Equivalence tests for the incremental planning engine.

The refactored hot path (run-length page bookkeeping, incrementally
maintained futures, heap-based Belady, bisect-based buffer lookup) must be
*behaviorally invisible*: every plan, eviction count, and simulation result
must match the straightforward reference implementations it replaced.
"""
import random

import pytest

from repro.core.hardware import RTX5080
from repro.core.hbm import HBMPool
from repro.core.memory_manager import TaskHelper, _page_order
from repro.core.opt import (
    PlannedAccess,
    belady_reference,
    belady_reference_scan,
    build_plan,
)
from repro.core.pages import (
    AddressSpace,
    RunSet,
    expand_runs,
    merge_runs,
    pages_to_runs,
    run_page_count,
)
from repro.core.planner import plan_switch
from repro.core.predictor import OraclePredictor
from repro.core.scheduler import RoundRobinPolicy
from repro.core.simulator import simulate
from repro.core.timeline import TaskTimeline, TimelineEntry
from repro.core.workloads import MatMulTask, VecAddTask, combo
from repro.core.commands import kernel


# --------------------------------------------------------------------------
# run-length primitives
# --------------------------------------------------------------------------


def test_page_runs_match_per_page_decode():
    space = AddressSpace(page_size=4096)
    bufs = [space.malloc(64 << 10) for _ in range(4)]
    rnd = random.Random(7)
    for _ in range(50):
        extents = []
        for _ in range(rnd.randrange(1, 8)):
            b = bufs[rnd.randrange(len(bufs))]
            off = rnd.randrange(0, b.size - 1)
            extents.append((b.base + off, rnd.randrange(1, b.size - off)))
        runs = space.page_runs_of_extents(extents)
        assert expand_runs(runs) == _page_order(space, extents)
        assert run_page_count(runs) == len(_page_order(space, extents))


def test_runset_first_touch_order():
    seen = RunSet()
    out = []
    ref_seen, ref_out = set(), []
    rnd = random.Random(3)
    for _ in range(200):
        s = rnd.randrange(0, 100)
        e = s + rnd.randrange(1, 20)
        out.extend(expand_runs(seen.add(s, e)))
        for p in range(s, e):
            if p not in ref_seen:
                ref_seen.add(p)
                ref_out.append(p)
    assert out == ref_out
    assert sorted(ref_seen) == expand_runs(seen.runs())


def test_merge_and_pages_roundtrip():
    rnd = random.Random(11)
    runs = [(s, s + rnd.randrange(1, 9)) for s in rnd.sample(range(200), 30)]
    merged = merge_runs(runs)
    assert expand_runs(merged) == sorted({p for s, e in runs for p in range(s, e)})
    pages = [5, 6, 7, 3, 10, 11, 2]
    assert expand_runs(pages_to_runs(pages)) == pages


def test_free_with_shared_base_zero_size_alloc():
    """malloc(0) shares its base with the next allocation; free() must remove
    exactly the requested buffer from the sorted index."""
    space = AddressSpace(page_size=4096)
    zero = space.malloc(0)
    real = space.malloc(8192)
    assert zero.base == real.base
    assert space.find_buffer(real.base) is real
    space.free(real)
    assert space.find_buffer(real.base + 1) is None
    space.free(zero)
    assert space.find_buffer(zero.base) is None


def test_find_buffer_bisect():
    space = AddressSpace(page_size=4096)
    bufs = [space.malloc((i + 1) << 12) for i in range(16)]
    for b in bufs:
        assert space.find_buffer(b.base) is b
        assert space.find_buffer(b.end - 1) is b
    # gaps between page-aligned allocations and out-of-range pointers
    assert space.find_buffer(bufs[0].base - 1) is None
    assert space.find_buffer(bufs[-1].end + (1 << 20)) is None
    freed = bufs[5]
    space.free(freed)
    assert space.find_buffer(freed.base) is None
    assert space.find_buffer(bufs[6].base) is bufs[6]


# --------------------------------------------------------------------------
# incremental future == from-scratch rebuild
# --------------------------------------------------------------------------


def _mk_helper(task_id=0, page_size=4096):
    space = AddressSpace(page_size=page_size, base=(task_id + 1) << 30)
    return TaskHelper(task_id, space, OraclePredictor()), space


def _rand_cmd(space, bufs, rnd, i):
    extents = []
    for _ in range(rnd.randrange(1, 5)):
        b = bufs[rnd.randrange(len(bufs))]
        off = rnd.randrange(0, b.size // 2)
        extents.append((b.base + off, rnd.randrange(1, b.size - off)))
    return kernel(f"k{i % 7}", (extents[0][0], i), float(rnd.randrange(1, 50)), extents)


def test_incremental_future_matches_rebuild():
    helper, space = _mk_helper()
    bufs = [space.malloc(128 << 10) for _ in range(6)]
    rnd = random.Random(42)
    for i in range(60):
        helper.launch(_rand_cmd(space, bufs, rnd, i))
        if rnd.random() < 0.4 and len(helper):
            helper.pop()
    for _ in range(900):  # drive past the compaction threshold
        helper.launch(_rand_cmd(space, bufs, rnd, 0))
        helper.pop()

    inc = helper.future()
    ref = helper.future_rebuild()
    assert [(a.task_id, a.seq_no, a.page_list(), a.latency_us) for a in inc] == [
        (a.task_id, a.seq_no, a.pages, a.latency_us) for a in ref
    ]
    # max_commands slicing agrees too
    inc5 = helper.future(max_commands=5)
    ref5 = helper.future_rebuild(max_commands=5)
    assert [a.page_list() for a in inc5] == [a.pages for a in ref5]


def test_pop_on_empty_queue_leaves_state_intact():
    helper, space = _mk_helper()
    bufs = [space.malloc(64 << 10)]
    rnd = random.Random(1)
    with pytest.raises(IndexError):
        helper.pop()
    helper.launch(_rand_cmd(space, bufs, rnd, 0))
    # planner state must still line up after the failed pop
    assert helper.head_index() == 0
    assert helper.consume_cut(0, 1e9) == 1
    assert len(helper.future()) == 1


def test_plan_tolerates_unregistered_task():
    helper, space = _mk_helper(0)
    bufs = [space.malloc(64 << 10)]
    rnd = random.Random(2)
    for i in range(4):
        helper.launch(_rand_cmd(space, bufs, rnd, i))
    helpers = {0: helper}
    tl = TaskTimeline([TimelineEntry(7, 100.0), TimelineEntry(0, 100.0)])
    plan = plan_switch(tl, helpers)
    ref = build_plan(tl, {0: helper.future_rebuild()})
    opt = plan.to_opt_plan(helpers)  # must not raise on task 7
    assert opt.timeslice_page_groups == ref.timeslice_page_groups
    assert opt.first_access_order == ref.first_access_order == []


def test_incremental_plan_matches_build_plan():
    rnd = random.Random(99)
    helpers = {}
    for tid in range(3):
        helper, space = _mk_helper(tid)
        bufs = [space.malloc(96 << 10) for _ in range(5)]
        for i in range(rnd.randrange(10, 30)):
            helper.launch(_rand_cmd(space, bufs, rnd, i))
        for _ in range(rnd.randrange(0, 8)):
            helper.pop()
        helpers[tid] = helper

    # integer-valued latencies make budget arithmetic exact, so the bisect
    # cut and the sequential budget walk provably agree
    tl = TaskTimeline(
        [TimelineEntry(tid % 3, float(rnd.randrange(20, 200))) for tid in range(6)]
    )
    ref = build_plan(tl, {tid: h.future_rebuild() for tid, h in helpers.items()})
    inc = plan_switch(tl, helpers).to_opt_plan(helpers)

    assert inc.timeslice_page_groups == ref.timeslice_page_groups
    assert inc.first_access_order == ref.first_access_order
    assert inc.global_sequence == ref.global_sequence


def test_planned_access_runs_and_pages_views_agree():
    acc = PlannedAccess(0, 0, [4, 5, 6, 2, 9], 1.0)
    assert expand_runs(acc.page_runs()) == [4, 5, 6, 2, 9]
    acc2 = PlannedAccess(0, 0, None, 1.0, runs=((4, 7), (2, 3)))
    assert acc2.page_list() == [4, 5, 6, 2]


# --------------------------------------------------------------------------
# heap Belady == scan Belady
# --------------------------------------------------------------------------


def test_belady_heap_matches_scan_randomized():
    rnd = random.Random(1234)
    for trial in range(60):
        n_pages = rnd.randrange(3, 40)
        capacity = rnd.randrange(2, 16)
        accesses = [
            [rnd.randrange(n_pages) for _ in range(rnd.randrange(1, 4))]
            for _ in range(rnd.randrange(5, 80))
        ]
        init = (
            set(rnd.sample(range(n_pages), min(n_pages, capacity)))
            if trial % 3 == 0
            else None
        )
        assert belady_reference(accesses, capacity, init) == belady_reference_scan(
            accesses, capacity, init
        ), (trial, capacity, accesses, init)


# --------------------------------------------------------------------------
# HBM pool: simplified migrate + run-based ops
# --------------------------------------------------------------------------


def test_migrate_eviction_counting():
    pool = HBMPool(4)
    for p in (1, 2, 3, 4):
        pool.populate(p)
    populated, evicted = pool.migrate([10, 11, 3])
    assert populated == [10, 11]
    assert evicted == [1, 2]
    assert pool.evictions == 2 and pool.populations == 6
    # resident page 3 was protected (moved to tail), not re-populated
    assert pool.eviction_order() == [4, 10, 11, 3]
    # migrating only-resident pages moves them without counters changing
    populated, evicted = pool.migrate([4])
    assert populated == [] and evicted == []
    assert pool.evictions == 2 and pool.populations == 6


def test_run_ops_match_page_ops():
    a, b = HBMPool(16), HBMPool(16)
    rnd = random.Random(5)
    for p in rnd.sample(range(64), 16):
        a.populate(p)
        b.populate(p)
    group = sorted(rnd.sample(range(64), 20))
    runs = merge_runs(pages_to_runs(group))
    assert a.madvise(group) == b.madvise_runs(runs)
    assert a.eviction_order() == b.eviction_order()
    want = [7, 8, 9, 40, 41]
    populated, evicted = b.migrate_runs(pages_to_runs(want))
    # run-native migrate returns runs; expanding them yields the page lists
    # the per-page API produces
    assert a.migrate(want) == (expand_runs(populated), expand_runs(evicted))
    assert a.eviction_order() == b.eviction_order()
    assert b.all_resident_runs(pages_to_runs(want))
    assert not b.all_resident_runs([(60, 64)])


# --------------------------------------------------------------------------
# end-to-end: incremental engine produces the identical SimResult
# --------------------------------------------------------------------------


def _run(planning, backend="msched", predictor="oracle"):
    progs = [
        VecAddTask(0, n_bytes=2 << 20, kernels_per_iter=3, page_size=64 << 10),
        MatMulTask(1, dim=512, n_matrices=6, page_size=64 << 10),
    ]
    foot = sum(p.footprint_bytes() for p in progs)
    return simulate(
        progs,
        RTX5080,
        backend,
        capacity_bytes=int(foot / 1.6),
        sim_us=120_000.0,
        policy=RoundRobinPolicy(5_000.0),
        predictor_kind=predictor,
        planning=planning,
    )


def test_simulation_identical_between_engines():
    for backend in ("msched", "ideal"):
        for predictor in ("oracle", "template"):
            new = _run("incremental", backend, predictor)
            old = _run("legacy", backend, predictor)
            assert new.sim_us == old.sim_us, (backend, predictor)
            assert new.faults == old.faults
            assert new.migrated_bytes == old.migrated_bytes
            assert new.switches == old.switches
            assert new.control_us == old.control_us
            for tid in new.per_task:
                a, b = new.per_task[tid], old.per_task[tid]
                assert (a.completions, a.commands, a.busy_us) == (
                    b.completions,
                    b.commands,
                    b.busy_us,
                )


def test_combo_smoke_with_incremental_engine():
    """A small combo-D-shaped scenario survives the full msched flow."""
    progs = combo("A", page_size=256 << 10, scale=0.05)
    foot = sum(p.footprint_bytes() for p in progs)
    res = simulate(
        progs,
        RTX5080,
        "msched",
        capacity_bytes=int(foot / 1.5),
        sim_us=100_000.0,
        policy=RoundRobinPolicy(10_000.0),
        predictor_kind="oracle",
    )
    assert res.total_completions() > 0
    assert res.switches > 0


def test_simresult_percentile_helpers():
    from repro.core.simulator import SimResult, TaskStats

    stats = TaskStats(latencies_us=[float(x) for x in range(100, 0, -1)])
    res = SimResult(1.0, {0: stats, 1: TaskStats()}, 0, 0, 0, 0.0)
    xs = sorted(stats.latencies_us)
    assert res.p50_latency_us(0) == xs[50]
    assert res.p99_latency_us(0) == xs[99]
    assert res.p99_latency_us(1) == 0.0
    assert res.p99_latency_us() == xs[99]  # aggregate over tasks
