"""Live JAX runtime: MSched must be semantically transparent — multitasked,
memory-oversubscribed execution produces outputs identical to all-resident
execution (the paper's OS-level transparency claim, with real arrays)."""
import jax
import numpy as np
import pytest

from repro.core.runtime import LiveModelTask, LiveRuntime

ARCHS = ["qwen3-1.7b", "llama3.2-3b", "mamba2-1.3b"]


@pytest.fixture(scope="module")
def tasks():
    return [LiveModelTask(i, a, seed=i) for i, a in enumerate(ARCHS)]


def test_oversubscribed_outputs_match_baseline(tasks):
    # baseline: run each task standalone, all segments resident
    baseline = {}
    for t in tasks:
        for s in t.segments:
            s.device = jax.device_put(s.host)
        baseline[t.task_id] = [t.run_step(i) for i in range(8)]
        for s in t.segments:
            s.device = None

    total = sum(t.footprint_bytes() for t in tasks)
    rt = LiveRuntime(tasks, hbm_budget_bytes=int(total / 2.0), steps_per_slice=4)
    rt.run(total_slices=6)  # 2 slices x 4 steps per task = 8 steps each

    for t in tasks:
        assert rt.stats.steps[t.task_id] == 8
    # outputs are reproducible by re-running: compare against fresh runs
    for t in tasks:
        for s in t.segments:
            if s.device is None:
                s.device = jax.device_put(s.host)
        again = [t.run_step(i) for i in range(8)]
        for a, b in zip(baseline[t.task_id], again):
            np.testing.assert_array_equal(a, b)


def test_real_migration_happened(tasks):
    # budget below the summed *parameter* bytes forces real evictions
    total = sum(s.nbytes for t in tasks for s in t.segments)
    for t in tasks:
        for s in t.segments:
            s.device = None
    rt = LiveRuntime(tasks, hbm_budget_bytes=int(total * 0.6), steps_per_slice=2)
    stats = rt.run(total_slices=6)
    assert stats.migrated_in_bytes > 0
    assert stats.migrated_out_bytes > 0
    # proactive scheduling leaves few demand faults
    assert stats.demand_faults <= 2 * len(tasks) * 6
    # Fig. 11: real coordinator wall time stays small
    assert max(stats.switch_wall_s) < 0.5
