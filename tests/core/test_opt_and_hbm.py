"""OPT planner + HBM eviction-list tests: the madvise walk must realize
Belady's optimal replacement (paper §6.2, Fig. 4)."""
import random

import pytest

try:  # optional dev dependency (requirements-dev.txt)
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:
    st = None

from repro.core.hbm import HBMPool
from repro.core.opt import PlannedAccess, belady_reference, build_plan
from repro.core.timeline import TaskTimeline, TimelineEntry


def test_build_plan_consumes_timeslices():
    tl = TaskTimeline([TimelineEntry(0, 100.0), TimelineEntry(1, 100.0)])
    futures = {
        0: [PlannedAccess(0, i, [i], 60.0) for i in range(4)],
        1: [PlannedAccess(1, i, [100 + i], 30.0) for i in range(4)],
    }
    plan = build_plan(tl, futures)
    # 100us at 60us/cmd -> two commands of task 0 fit the first slice
    assert plan.timeslice_page_groups[0] == {0, 1}
    assert plan.timeslice_page_groups[1] == {100, 101, 102, 103}
    assert plan.first_access_order == [0, 1]


def test_fig4_eviction_order():
    """Reproduces the paper's Fig. 4 walkthrough: after the reverse madvise
    walk, the eviction list is [unreferenced, task3's, task2's, task1's]."""
    pool = HBMPool(capacity_pages=8)
    # resident pages: task1 {1,2}, task2 {3,4}, task3 {5,6}, unreferenced {7,8}
    for p in (1, 2, 3, 4, 5, 6, 7, 8):
        pool.populate(p)
    tl = TaskTimeline(
        [TimelineEntry(1, 20_000.0), TimelineEntry(2, 10_000.0), TimelineEntry(3, 30_000.0)]
    )
    futures = {
        1: [PlannedAccess(1, 0, [1, 2], 1.0)],
        2: [PlannedAccess(2, 0, [3, 4], 1.0)],
        3: [PlannedAccess(3, 0, [5, 6], 1.0)],
    }
    plan = build_plan(tl, futures)
    for group in reversed(plan.timeslice_page_groups):
        pool.madvise(sorted(group))
    order = pool.eviction_order()
    assert order[:2] == [7, 8]  # grey: unreferenced across the timeline
    assert set(order[2:4]) == {5, 6}  # orange: task3 (farthest future)
    assert set(order[4:6]) == {3, 4}  # pink: task2
    assert set(order[6:8]) == {1, 2}  # cyan: task1 (next to run — protected)


def _check_madvise_walk_matches_belady(seed, capacity, n_pages, n_access):
    """The list mechanism's migration volume equals exact Belady OPT when the
    plan is re-derived before every access group (the paper's claim that
    per-switch re-planning keeps the order 'effectively optimal')."""
    rnd = random.Random(seed)
    accesses = [[rnd.randrange(n_pages)] for _ in range(n_access)]

    # exact OPT
    opt_misses, _ = belady_reference(accesses, capacity)

    # list mechanism: single task, one access per "timeslice"
    pool = HBMPool(capacity)
    misses = 0
    for i, group in enumerate(accesses):
        # madvise walk over the remaining horizon, reverse order
        horizon = accesses[i:]
        for future_group in reversed(horizon):
            pool.madvise(future_group)
        for p in group:
            if not pool.resident(p):
                misses += 1
                pool.populate(p)
    assert misses == opt_misses


if st is not None:

    @settings(max_examples=30, deadline=None)
    @given(
        seed=st.integers(0, 99999),
        capacity=st.integers(3, 12),
        n_pages=st.integers(4, 24),
        n_access=st.integers(5, 60),
    )
    def test_property_madvise_walk_matches_belady(seed, capacity, n_pages, n_access):
        _check_madvise_walk_matches_belady(seed, capacity, n_pages, n_access)

else:  # deterministic fallback when hypothesis is unavailable

    @pytest.mark.parametrize("seed", range(30))
    def test_property_madvise_walk_matches_belady(seed):
        rnd = random.Random(1000 + seed)
        _check_madvise_walk_matches_belady(
            seed,
            rnd.randint(3, 12),
            rnd.randint(4, 24),
            rnd.randint(5, 60),
        )


def test_madvise_protects_tail():
    pool = HBMPool(3)
    for p in (1, 2, 3):
        pool.populate(p)
    pool.madvise([1])  # 1 moves to tail; eviction order now 2,3,1
    assert pool.eviction_order() == [2, 3, 1]
    evicted = pool.populate(4)
    assert evicted == [2]


def test_migrate_populates_in_order_and_counts():
    pool = HBMPool(4)
    for p in (1, 2, 3, 4):
        pool.populate(p)
    populated, evicted = pool.migrate([10, 11])
    assert populated == [10, 11]
    assert evicted == [1, 2]
    assert pool.resident(10) and not pool.resident(1)
