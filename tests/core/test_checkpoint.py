"""Checkpointing coverage: round-trip fidelity, retention, size accounting,
async overlap, and the working-set manifests the inter-GPU migration path
stages through the same format."""
import os

import numpy as np
import pytest

jax = pytest.importorskip("jax")
msgpack = pytest.importorskip("msgpack")

from repro.checkpointing import checkpoint
from repro.cluster.migration import (
    checkpoint_roundtrip,
    pack_working_set,
    unpack_working_set,
)
from repro.core.simulator import EjectedTask
from repro.core.workloads import VecAddTask


def _tree():
    return {
        "params": {
            "w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": np.ones((4,), np.float64),
        },
        "step_scale": np.int64(7),
        "stack": [np.zeros((2, 2), np.int32), np.full((3,), 2.5, np.float32)],
    }


def _like(tree):
    return jax.tree.map(lambda a: np.zeros_like(a), tree)


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    d = checkpoint.save(str(tmp_path), 3, tree)
    assert os.path.basename(d) == "step_00000003"
    restored = checkpoint.restore(str(tmp_path), 3, _like(tree))
    flat_a = jax.tree.leaves(tree)
    flat_b = jax.tree.leaves(restored)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        b = np.asarray(b)
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(a, b)


def test_bfloat16_roundtrip(tmp_path):
    """ml_dtypes leaves survive the uint-view detour."""
    tree = {"w": jax.numpy.arange(8, dtype=jax.numpy.bfloat16)}
    checkpoint.save(str(tmp_path), 0, tree)
    restored = checkpoint.restore(str(tmp_path), 0, _like(tree))
    out = np.asarray(restored["w"])
    assert out.dtype == jax.numpy.bfloat16
    np.testing.assert_array_equal(out, np.asarray(tree["w"]))


def test_retention_and_latest_step(tmp_path):
    assert checkpoint.latest_step(str(tmp_path)) is None
    for step in (1, 2, 5, 9):
        checkpoint.save(str(tmp_path), step, {"x": np.int64(step)}, keep=2)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000005", "step_00000009"]
    assert checkpoint.latest_step(str(tmp_path)) == 9


def test_manifest_size_accounting(tmp_path):
    """The manifest's dtype/shape entries account for every staged byte."""
    tree = _tree()
    d = checkpoint.save(str(tmp_path), 0, tree)
    with open(os.path.join(d, checkpoint.MANIFEST), "rb") as f:
        meta = msgpack.unpackb(f.read())
    leaves = jax.tree.leaves(tree)
    assert len(meta["leaves"]) == len(leaves)
    manifest_bytes = sum(
        np.dtype(e["dtype"]).itemsize * int(np.prod(e["shape"], dtype=np.int64))
        for e in meta["leaves"]
    )
    assert manifest_bytes == sum(a.nbytes for a in leaves)
    # every referenced shard exists on disk
    for e in meta["leaves"]:
        assert os.path.exists(os.path.join(d, e["file"]))


def test_atomic_overwrite(tmp_path):
    checkpoint.save(str(tmp_path), 1, {"x": np.int64(1)})
    checkpoint.save(str(tmp_path), 1, {"x": np.int64(2)})
    restored = checkpoint.restore(str(tmp_path), 1, {"x": np.zeros((), np.int64)})
    assert int(restored["x"]) == 2
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_async_checkpointer_overlap_and_errors(tmp_path):
    ck = checkpoint.AsyncCheckpointer(str(tmp_path), keep=3)
    ck.save_async(4, {"x": np.arange(3)})
    ck.wait()
    assert checkpoint.latest_step(str(tmp_path)) == 4
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("")
    bad = checkpoint.AsyncCheckpointer(str(blocker / "x"))
    bad.save_async(0, {"x": np.arange(3)})
    with pytest.raises(Exception):
        bad.wait()
    # the error is consumed: the checkpointer is reusable afterwards
    assert bad.last_error is None


# --------------------------------------------------------------------------
# Working-set manifests (the inter-GPU migration path)
# --------------------------------------------------------------------------


def _ejected(runs):
    prog = VecAddTask(5, n_bytes=64 << 10, page_size=4096)
    return EjectedTask(
        program=prog, completed=3, resident_runs=list(runs), record=None
    )


def test_working_set_pack_unpack():
    runs = [(100, 140), (200, 201), (512, 600)]
    tree = pack_working_set(_ejected(runs), 4096)
    assert unpack_working_set(tree) == runs
    assert int(tree["completed"]) == 3
    assert int(tree["page_size"]) == 4096


def test_working_set_checkpoint_roundtrip_partial(tmp_path):
    """A *partial* working set (only some of the footprint resident at
    ejection) survives the staged checkpoint exactly."""
    runs = [(0, 7), (9, 10), (64, 96)]
    ej = _ejected(runs)
    restored = checkpoint_roundtrip(str(tmp_path), 0, ej, 4096)
    assert restored == runs
    assert checkpoint.latest_step(str(tmp_path)) == 0
    # empty working set round-trips too (a task ejected before it ever ran)
    assert checkpoint_roundtrip(str(tmp_path), 1, _ejected([]), 4096) == []


def test_working_set_checkpoint_detects_stale_manifest(tmp_path, monkeypatch):
    """A seq collision on the stage dir (restoring another task's manifest)
    fails loud instead of warming the wrong pages onto the target GPU."""
    checkpoint_roundtrip(str(tmp_path), 0, _ejected([(0, 4)]), 4096)  # task 5
    other = _ejected([(8, 12)])
    other.program.task_id = 99
    # simulate the collision: the save half is lost, the restore half reads
    # task 5's staged manifest
    monkeypatch.setattr(checkpoint, "save", lambda *a, **kw: None)
    with pytest.raises(RuntimeError, match="round-trip mismatch"):
        checkpoint_roundtrip(str(tmp_path), 0, other, 4096)
