"""System-behaviour tests for the multitasking simulator: the paper's key
claims must hold as *invariants*, not just as benchmark numbers."""
import pytest

from repro.core.hardware import RTX3080, RTX5080
from repro.core.migration import effective_swap_bandwidth_gbps, migrate_time_us
from repro.core.scheduler import PriorityPolicy, RoundRobinPolicy
from repro.core.simulator import simulate
from repro.core.workloads import LLMDecodeTask, MatMulTask, VecAddTask


@pytest.fixture(scope="module")
def llm_pair():
    return [
        LLMDecodeTask(0, page_size=1 << 20, max_context=1024),
        LLMDecodeTask(1, page_size=1 << 20, max_context=1024),
    ]


def _thr(progs, backend, cap_ratio, quantum=350_000.0, **kw):
    foot = sum(p.footprint_bytes() for p in progs)
    res = simulate(
        progs,
        RTX5080,
        backend,
        capacity_bytes=int(foot / cap_ratio),
        sim_us=2_000_000,
        policy=RoundRobinPolicy(quantum),
        **kw,
    )
    return res


def test_no_oversubscription_negligible_overhead(llm_pair):
    """At 100% subscription MSched must retain ~all of the UM throughput
    (paper: 99.41%)."""
    um = _thr(llm_pair, "um", 0.95).throughput_per_s()
    ms = _thr(llm_pair, "msched", 0.95).throughput_per_s()
    assert ms >= 0.97 * um


def test_msched_beats_um_under_pressure():
    # three instances as in the paper's D-Light (UM's LRU survives 2-task
    # round-robin but collapses at >=3-way interleaving)
    progs = [
        LLMDecodeTask(i, page_size=1 << 20, max_context=1024) for i in range(3)
    ]
    um = _thr(progs, "um", 1.5, quantum=2_000.0).throughput_per_s()
    ms = _thr(progs, "msched", 1.5).throughput_per_s()
    assert ms > 5 * um, (ms, um)


def test_msched_near_ideal(llm_pair):
    ms = _thr(llm_pair, "msched", 1.5).throughput_per_s()
    ideal = _thr(llm_pair, "ideal", 1.5).throughput_per_s()
    assert ms >= 0.85 * ideal


def test_msched_eliminates_faults(llm_pair):
    """Proactive scheduling leaves only sporadic faults (predictor F−≈0)."""
    um = _thr(llm_pair, "um", 1.5, quantum=2_000.0)
    ms = _thr(llm_pair, "msched", 1.5)
    assert um.faults > 1000
    assert ms.faults <= um.faults / 100


def test_allocation_prediction_inflates_migration(llm_pair):
    """Fig. 8: allocation-granularity prediction wastes bandwidth (per-step
    migration inflation) and under heavy pressure over-prediction displaces
    the active working set — the paper's 15.67x throughput collapse."""
    tmpl = _thr(llm_pair, "msched", 1.3, quantum=5_000.0)
    alloc = _thr(llm_pair, "msched", 1.3, quantum=5_000.0, predictor_kind="allocation")
    per_step = lambda r: r.migrated_bytes / max(r.total_completions(), 1)
    assert per_step(alloc) >= 1.15 * per_step(tmpl), (
        per_step(alloc),
        per_step(tmpl),
    )
    assert tmpl.throughput_per_s() >= alloc.throughput_per_s()

    # heavy pressure: the over-predicted working set exceeds capacity and
    # displaces itself — throughput collapses
    tmpl_h = _thr(llm_pair, "msched", 2.0, quantum=5_000.0)
    alloc_h = _thr(llm_pair, "msched", 2.0, quantum=5_000.0, predictor_kind="allocation")
    assert alloc_h.throughput_per_s() <= 0.5 * tmpl_h.throughput_per_s()


def test_pipelined_migration_speedup():
    """Fig. 9a: full-duplex pipelining beats serialized swap by ~1.5-1.8x."""
    for platform, lo, hi in ((RTX5080, 1.3, 1.8), (RTX3080, 1.5, 2.0)):
        n = 256 << 20
        plain = effective_swap_bandwidth_gbps(platform, n, pipelined=False)
        piped = effective_swap_bandwidth_gbps(platform, n, pipelined=True)
        assert lo <= piped / plain <= hi, (platform.name, piped / plain)


def test_pipeline_monotone_in_bytes():
    t1 = migrate_time_us(RTX5080, 1 << 20, 1 << 20)
    t2 = migrate_time_us(RTX5080, 2 << 20, 2 << 20)
    assert t2 > t1


def test_priority_policy_rt_latency():
    """Fig. 13: under priority scheduling, RT latency is bounded while BE
    still makes progress."""
    rt = MatMulTask(0, dim=1024, n_matrices=4, page_size=256 << 10)
    be = VecAddTask(1, n_bytes=64 << 20, page_size=256 << 10)
    arrivals = {0: [float(i * 200_000) for i in range(8)]}
    foot = rt.footprint_bytes() + be.footprint_bytes()
    res = simulate(
        [rt, be],
        RTX5080,
        "msched",
        capacity_bytes=int(foot / 1.5),
        sim_us=1_800_000,
        policy=PriorityPolicy(quantum_us=50_000.0),
        arrivals=arrivals,
        priorities={0: 10, 1: 0},
    )
    assert res.per_task[0].latencies_us, "RT requests must complete"
    assert res.per_task[1].completions > 0, "BE must not starve"


def test_throughput_scales_with_capacity(llm_pair):
    t_low = _thr(llm_pair, "msched", 2.0).throughput_per_s()
    t_high = _thr(llm_pair, "msched", 1.2).throughput_per_s()
    assert t_high >= t_low
