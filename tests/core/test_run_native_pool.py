"""Run-native memory hierarchy equivalence suite.

The run-native ``HBMPool`` (interval segments + LRU chain), the vectorized
``DemandPager`` fault path, the run-native migration schedule, and the
simulator's macro-stepper must all be *behaviorally invisible*: every
residency decision, eviction order, counter, stall time, and SimResult must
match the per-page reference implementations (``HBMPoolPaged`` + scalar
loops) bit for bit. The golden fingerprints at the bottom were recorded on
the pre-refactor engine (PR 2) for all four backends.
"""
import random

import pytest

try:  # optional dev dependency (requirements-dev.txt)
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:
    st = None

from repro.core.demand_paging import DemandPager
from repro.core.hardware import RTX5080
from repro.core.hbm import HBMPool, HBMPoolPaged, make_pool
from repro.core.migration import plan_population, plan_population_runs
from repro.core.pages import (
    clip_runs,
    expand_runs,
    merge_runs,
    pages_to_runs,
    run_page_count,
)
from repro.core.scheduler import RoundRobinPolicy
from repro.core.simulator import simulate
from repro.core.workloads import LLMDecodeTask, MatMulTask, VecAddTask, combo


# --------------------------------------------------------------------------
# randomized op-sequence equivalence: HBMPool vs HBMPoolPaged
# --------------------------------------------------------------------------


def _rand_runs(rnd, n_pages, max_runs=4):
    runs = []
    for _ in range(rnd.randrange(1, max_runs + 1)):
        s = rnd.randrange(0, n_pages)
        runs.append((s, s + rnd.randrange(1, max(2, n_pages // 4))))
    return runs


def _pool_state(pool):
    return (
        pool.eviction_order(),
        pool.resident_count(),
        pool.evictions,
        pool.populations,
        pool.freed_pages,
    )


def _check_op_sequence_equivalence(seed, capacity, n_pages, n_ops):
    """Drive both pools through an identical mixed op sequence and assert
    identical residency, eviction order, and counters after every op."""
    rnd = random.Random(seed)
    a, b = HBMPool(capacity), HBMPoolPaged(capacity)
    spans = {}
    for step in range(n_ops):
        op = rnd.randrange(9)
        if op == 0:
            p = rnd.randrange(n_pages)
            assert a.populate(p) == b.populate(p)
        elif op == 1:
            runs = _rand_runs(rnd, n_pages)
            ra = a.migrate_runs(runs)
            rb = b.migrate_runs(runs)
            assert tuple(map(expand_runs, ra)) == tuple(map(expand_runs, rb))
        elif op == 2:
            group = merge_runs(_rand_runs(rnd, n_pages))
            assert a.madvise_runs(group) == b.madvise_runs(group)
        elif op == 3:
            runs = _rand_runs(rnd, n_pages)
            a.touch_runs(runs)
            b.touch_runs(runs)
        elif op == 4:
            runs = _rand_runs(rnd, n_pages)
            a.drop_runs(runs)
            b.drop_runs(runs)
        elif op == 5:
            tid = rnd.randrange(4)
            if tid in spans:
                assert a.free_task(tid) == b.free_task(tid)
                del spans[tid]
            else:
                s = rnd.randrange(0, n_pages)
                span = (s, s + rnd.randrange(1, n_pages // 2 + 1))
                spans[tid] = span
                a.register_task(tid, span)
                b.register_task(tid, span)
        elif op == 6:
            p = rnd.randrange(n_pages)
            a.touch(p)
            b.touch(p)
        elif op == 7:
            runs = _rand_runs(rnd, n_pages)
            assert expand_runs(a.missing_runs(runs)) == expand_runs(
                b.missing_runs(runs)
            )
            assert a.all_resident_runs(runs) == b.all_resident_runs(runs)
        else:
            # demote (linger scavenging): disjoint input per the contract
            group = merge_runs(_rand_runs(rnd, n_pages))
            assert a.demote_runs(group) == b.demote_runs(group)
        assert _pool_state(a) == _pool_state(b), (seed, step, op)
    assert list(a.iter_eviction()) == list(b.iter_eviction())
    assert expand_runs(a.eviction_runs()) == expand_runs(b.eviction_runs())


if st is not None:

    @settings(max_examples=40, deadline=None)
    @given(
        seed=st.integers(0, 99999),
        capacity=st.integers(2, 24),
        n_pages=st.integers(8, 64),
        n_ops=st.integers(10, 80),
    )
    def test_property_pool_op_sequence_equivalence(seed, capacity, n_pages, n_ops):
        _check_op_sequence_equivalence(seed, capacity, n_pages, n_ops)

else:  # deterministic fallback when hypothesis is unavailable

    @pytest.mark.parametrize("seed", range(40))
    def test_property_pool_op_sequence_equivalence(seed):
        rnd = random.Random(7000 + seed)
        _check_op_sequence_equivalence(
            seed,
            rnd.randint(2, 24),
            rnd.randint(8, 64),
            rnd.randint(10, 80),
        )


# --------------------------------------------------------------------------
# migrate_runs golden: run-granularity (populated, evicted) semantics
# --------------------------------------------------------------------------


def test_migrate_runs_golden_run_semantics():
    """The run-native default returns *runs* whose expansion is exactly the
    page-level (populated, evicted) the per-page path produces — including
    protection of resident stretches and head-order victim identity."""
    run = HBMPool(8)
    paged = HBMPoolPaged(8)
    for p in (10, 11, 12, 30, 31, 50):
        run.populate(p)
        paged.populate(p)
    want = [(10, 14), (29, 32)]  # mixes resident stretches and gaps
    pop_r, ev_r = run.migrate_runs(want)
    pop_p, ev_p = paged.migrate(p for s, e in want for p in range(s, e))
    assert expand_runs(pop_r) == pop_p == [13, 29]
    assert expand_runs(ev_r) == ev_p == []
    assert run.eviction_order() == paged.eviction_order() == [
        50, 10, 11, 12, 13, 29, 30, 31,
    ]
    # under pressure, victims cascade into the migrating group itself: pages
    # protected early can be reclaimed to make room for later misses
    pop_r, ev_r = run.migrate_runs([(60, 66)])
    pop_p, ev_p = paged.migrate(range(60, 66))
    assert expand_runs(pop_r) == pop_p == list(range(60, 66))
    assert expand_runs(ev_r) == ev_p == [50, 10, 11, 12, 13, 29]
    assert run.eviction_order() == paged.eviction_order()
    # a run larger than the whole pool: leading pages are populated then
    # reclaimed before the tail lands (per-page loop dynamics)
    pop_r, ev_r = run.migrate_runs([(100, 120)])
    pop_p, ev_p = paged.migrate(range(100, 120))
    assert expand_runs(pop_r) == pop_p == list(range(100, 120))
    assert expand_runs(ev_r) == ev_p
    assert run.eviction_order() == paged.eviction_order() == list(range(112, 120))
    assert (run.populations, run.evictions) == (
        paged.populations,
        paged.evictions,
    )


# --------------------------------------------------------------------------
# DemandPager: vectorized fault servicing == per-page reference
# --------------------------------------------------------------------------


def _drive_pagers(seed, page_size, capacity):
    """Random access patterns through access_runs (run pool) vs access
    (paged pool): stalls and stats must match bit for bit."""
    rnd = random.Random(seed)
    run_pool, paged_pool = HBMPool(capacity), HBMPoolPaged(capacity)
    a = DemandPager(RTX5080, run_pool, page_size)
    b = DemandPager(RTX5080, paged_pool, page_size)
    for _ in range(12):
        runs = pages_to_runs(
            sorted(set(rnd.sample(range(160), rnd.randrange(1, 60))))
        )
        sa = a.access_runs(runs)
        sb = b.access(expand_runs(runs))
        assert sa == sb, (seed, page_size, capacity)
        assert a.stats == b.stats
        assert run_pool.eviction_order() == paged_pool.eviction_order()
        assert (run_pool.evictions, run_pool.populations) == (
            paged_pool.evictions,
            paged_pool.populations,
        )


@pytest.mark.parametrize("page_size", [4096, 16 << 10, 64 << 10, 1 << 20])
def test_pager_vectorized_matches_reference(page_size):
    for seed in range(6):
        rnd = random.Random(seed)
        _drive_pagers(seed, page_size, rnd.randrange(4, 140))


def test_batch_evict_single_resident_page_regression():
    """Regression (over-eviction edge): with one resident page and a full
    capacity-1 pool, the batch path must stand down — populate's own head
    eviction makes room — instead of batch-reclaiming the only page. Counts
    stay identical because the eviction moves to populate."""
    pool = HBMPool(1)
    pager = DemandPager(RTX5080, pool, 1 << 20)
    pool.populate(7)
    assert pool.resident_count() == 1 and pool.free_pages() == 0
    pager._batch_evict(batch=8)
    # the batch path evicted nothing: the sole resident page survives
    assert pool.resident(7) and pool.resident_count() == 1
    assert pager.stats.evicted_pages == 0
    # a faulting access still makes progress, with exactly one eviction
    stall = pager.access_runs([(9, 10)])
    assert stall > 0
    assert pool.eviction_order() == [9]
    assert pager.stats.evicted_pages == 1 and pool.evictions == 1
    # and the paged reference agrees end to end
    ppool = HBMPoolPaged(1)
    ppager = DemandPager(RTX5080, ppool, 1 << 20)
    ppool.populate(7)
    assert ppager.access([9]) == stall
    assert ppager.stats == pager.stats
    assert ppool.eviction_order() == [9]


# --------------------------------------------------------------------------
# run-native migration schedule == per-page plan_population
# --------------------------------------------------------------------------


@pytest.mark.parametrize("pipelined", [True, False])
def test_plan_population_runs_matches_per_page(pipelined):
    rnd = random.Random(3)
    ps = 1 << 20
    for trial in range(20):
        n_runs = rnd.randrange(0, 6)
        runs, base = [], 0
        for _ in range(n_runs):
            base += rnd.randrange(1, 50)
            runs.append((base, base + rnd.randrange(1, 40)))
            base = runs[-1][1]
        rnd.shuffle(runs)  # population order != ascending page order
        evict = rnd.randrange(0, 2 * max(1, run_page_count(runs)))
        ref = plan_population(RTX5080, expand_runs(runs), evict, pipelined, ps)
        new = plan_population_runs(RTX5080, runs, evict, pipelined, ps)
        assert new.evict_bytes == ref.evict_bytes
        assert new.populate_bytes == ref.populate_bytes
        assert new.total_us == ref.total_us
        assert new.page_ready_us == ref.page_ready_us
        # the run-queryable view answers the same max the per-page dict scan
        # produced, for arbitrary query runs
        view = new.ready_view(base=123.5)
        ref_view = ref.ready_view(base=123.5)
        if view is None:
            assert ref_view is None
            continue
        assert view.global_max == ref_view.global_max
        for _ in range(10):
            q = _rand_runs(rnd, base + 10)
            assert view.max_ready(q) == ref_view.max_ready(q), (trial, q)


# --------------------------------------------------------------------------
# full-stack: pool="paged" is a bit-for-bit equivalence mode; the
# macro-stepper (run pool, incremental planning) changes nothing
# --------------------------------------------------------------------------


def _fingerprint(res):
    return (
        res.sim_us,
        res.switches,
        res.faults,
        res.migrated_bytes,
        res.control_us,
        res.total_completions(),
        tuple(
            (tid, s.completions, s.commands, s.busy_us)
            for tid, s in sorted(res.per_task.items())
        ),
    )


def _combo_small(backend, pool_kind, planning="incremental"):
    progs = [
        VecAddTask(0, n_bytes=2 << 20, kernels_per_iter=3, page_size=16 << 10),
        MatMulTask(1, dim=512, n_matrices=6, page_size=16 << 10),
    ]
    foot = sum(p.footprint_bytes() for p in progs)
    return simulate(
        progs,
        RTX5080,
        backend,
        capacity_bytes=int(foot / 1.6),
        sim_us=120_000.0,
        policy=RoundRobinPolicy(5_000.0),
        predictor_kind="oracle",
        planning=planning,
        pool=pool_kind,
    )


@pytest.mark.parametrize("backend", ["um", "msched", "ideal", "suv"])
def test_paged_pool_mode_bit_for_bit(backend):
    """run-native pool + vectorized pager + macro-stepper vs per-page pool +
    scalar pager (the complete pre-refactor execution path)."""
    assert _fingerprint(_combo_small(backend, "run")) == _fingerprint(
        _combo_small(backend, "paged")
    )


def test_macro_step_invariant_under_pressure_and_slack():
    """Macro-stepping fires when working sets are resident (slack capacity)
    and must be inert either way: identical SimResult vs the legacy planner,
    which never macro-steps."""
    for cap_ratio in (0.8, 1.6):  # slack and oversubscribed
        progs = [
            LLMDecodeTask(0, page_size=1 << 20, max_context=512),
            LLMDecodeTask(1, page_size=1 << 20, max_context=512),
        ]
        foot = sum(p.footprint_bytes() for p in progs)
        kw = dict(
            capacity_bytes=int(foot / cap_ratio),
            sim_us=300_000.0,
            policy=RoundRobinPolicy(50_000.0),
            predictor_kind="oracle",
        )
        new = simulate(progs, RTX5080, "msched", planning="incremental", **kw)
        progs2 = [
            LLMDecodeTask(0, page_size=1 << 20, max_context=512),
            LLMDecodeTask(1, page_size=1 << 20, max_context=512),
        ]
        old = simulate(progs2, RTX5080, "msched", planning="legacy", **kw)
        assert _fingerprint(new) == _fingerprint(old), cap_ratio


def test_make_pool_kinds():
    assert isinstance(make_pool("run", 4), HBMPool)
    assert isinstance(make_pool("paged", 4), HBMPoolPaged)
    with pytest.raises(ValueError, match="pool kind"):
        make_pool("nope", 4)
    with pytest.raises(ValueError, match="pool kind"):
        simulate([], RTX5080, "um", sim_us=1.0, pool="nope")


def test_clip_runs():
    runs = [(0, 4), (10, 12), (20, 25)]
    assert clip_runs(runs, 5) == [(0, 4), (10, 11)]
    assert clip_runs(runs, 0) == []
    assert expand_runs(clip_runs(runs, 100)) == expand_runs(runs)


# --------------------------------------------------------------------------
# golden fingerprints: pre-refactor engine values, all four backends
# --------------------------------------------------------------------------

_STATIC_GOLDEN = {
    "um": (100261.51447250205, 10, 315, 328466432, 0.0, 5190),
    "msched": (103033.16203421363, 10, 0, 130809856, 2830.7400000000002, 5973),
    "ideal": (100188.02527081216, 10, 0, 130809856, 0.0, 5977),
    "suv": (100096.70406610666, 10, 0, 284950528, 0.0, 5546),
}


@pytest.mark.parametrize("backend", ["um", "msched", "ideal", "suv"])
def test_static_combo_golden_all_backends(backend):
    """Recorded on the pre-run-native engine (PR 2 tree): the run-native
    hierarchy + macro-stepper must be execution-invisible for every
    backend, not just msched."""
    progs = combo("A", page_size=256 << 10, scale=0.05)
    foot = sum(p.footprint_bytes() for p in progs)
    res = simulate(
        progs, RTX5080, backend, capacity_bytes=int(foot / 1.5),
        sim_us=100_000.0, policy=RoundRobinPolicy(10_000.0),
        predictor_kind="oracle",
    )
    assert _fingerprint(res)[:6] == _STATIC_GOLDEN[backend]


_SERVE_GOLDEN = {
    "um": (10002034.794667574, 1809, 118019, 123751890944, 0.0, 73),
    "msched": (1525606.3654503059, 13, 0, 26937917440, 390.0, 145),
    "ideal": (1525426.3654503212, 13, 0, 26937917440, 0.0, 145),
    "suv": (10046655.501572613, 107, 0, 247296163840, 0.0, 1),
}


@pytest.mark.parametrize("backend", ["um", "msched", "ideal", "suv"])
def test_seeded_serving_trace_golden_all_backends(backend):
    """Same contract through the dynamic lifecycle: the seeded serving trace
    (template predictor, admission control, task retirement) fingerprints
    were recorded on the pre-run-native engine."""
    from repro.serving import (
        AlwaysAdmit,
        MSchedAdmission,
        SLOSpec,
        poisson_trace,
        serve_trace,
    )
    from repro.serving.lifecycle import ServedRequestTask

    tr = poisson_trace(
        4.0, 1.5, seed=7, tenants=("qwen3-1.7b",), prompt_mean=128,
        output_mean=12, max_prompt=256, max_output=24,
    )
    probe = ServedRequestTask(999, tr.requests[0], page_size=1 << 20)
    cap = int(3 * probe.footprint_bytes() / 1.5)
    adm, q = (
        (MSchedAdmission(headroom=0.9), 350_000.0)
        if backend in ("msched", "ideal")
        else (AlwaysAdmit(), 2_000.0)
    )
    rep = serve_trace(
        tr, RTX5080, backend=backend, capacity_bytes=cap, admission=adm,
        policy=RoundRobinPolicy(q), page_size=1 << 20,
        slo=SLOSpec(ttft_us=2e6, tpot_us=50e3),
    )
    assert _fingerprint(rep.result)[:6] == _SERVE_GOLDEN[backend]
