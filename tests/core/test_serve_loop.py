"""MultiModelServer: the live (real-JAX) serving loop hosting several models
on one device budget with MSched-style proactive migration."""
import pytest

from repro.runtime.serve_loop import MultiModelServer, Request

ARCHS = ["qwen3-1.7b", "mamba2-1.3b"]


@pytest.fixture(scope="module")
def server():
    return MultiModelServer(ARCHS, steps_per_slice=2)


def test_server_setup_oversubscribed(server):
    total = sum(t.footprint_bytes() for t in server.runtime.tasks.values())
    budget = server.runtime.pool.capacity * server.runtime.page_size
    assert budget < total  # 150% oversubscription by default
    assert set(server.queues) == {0, 1}


def test_serve_drains_queues_fifo(server):
    for i in range(3):
        server.submit(Request(model=0, arrival_s=0.1 * i))
        server.submit(Request(model=1, arrival_s=0.05 + 0.1 * i))
    stats = server.serve(wall_budget_s=60.0)
    assert stats.served[0] == 3
    assert stats.served[1] == 3
    assert not any(server.queues.values())
    # per-request latencies recorded and non-negative p99 for both models
    for m in (0, 1):
        assert len(stats.latencies_s[m]) == 3
        assert stats.p99(m) >= max(0.0, min(stats.latencies_s[m]))
    # oversubscribed hosting must have moved real bytes into the pool
    assert stats.migrated_in_bytes > 0


def test_serve_empty_queue_returns_immediately(server):
    stats = server.serve(wall_budget_s=5.0)
    assert sum(stats.served.values()) == 0
    assert all(not q for q in server.queues.values())


def test_p99_empty_model_is_zero(server):
    stats = server.serve(wall_budget_s=0.01)
    assert stats.p99(0) == 0.0
