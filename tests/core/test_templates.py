"""Unit + property tests for the template analyzer (paper §5.2)."""
import random

import pytest

try:  # optional dev dependency (requirements-dev.txt)
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:
    st = None

from repro.core.commands import kernel
from repro.core.pages import AddressSpace
from repro.core.predictor import TemplatePredictor, evaluate_accuracy
from repro.core.templates import (
    OPAQUE,
    T1_FIXED,
    T2_LINEAR,
    T3_STRIDED,
    analyze_kernel,
    analyze_traces,
)
from repro.core.trace import TraceStore


def _record(store, space, name, args, extents, lat=10.0):
    store.record(kernel(name, args, lat, extents), space=space)


def test_t1_fixed_size():
    space = AddressSpace(4096)
    buf = space.malloc(1 << 20)
    store = TraceStore()
    for i in range(4):
        _record(store, space, "k", (buf.base, 7 + i), [(buf.base, 64 << 10)])
    desc = analyze_kernel("k", store.by_kernel["k"])
    [f] = desc.formulas
    assert f.kind == T1_FIXED
    assert f.predict_extents((buf.base, 99)) == [(buf.base, 64 << 10)]


def test_t2_linear_single_arg():
    space = AddressSpace(4096)
    buf = space.malloc(8 << 20)
    store = TraceStore()
    for n in (100, 200, 300):
        _record(store, space, "k", (buf.base, n), [(buf.base, 4 * n)])
    desc = analyze_kernel("k", store.by_kernel["k"])
    [f] = desc.formulas
    assert f.kind == T2_LINEAR
    assert f.predict_extents((buf.base, 500)) == [(buf.base, 2000)]


def test_t2_linear_product_of_args():
    space = AddressSpace(4096)
    buf = space.malloc(64 << 20)
    store = TraceStore()
    for m, n in ((8, 16), (4, 4), (32, 8)):
        _record(store, space, "mm", (buf.base, m, n), [(buf.base, 2 * m * n)])
    desc = analyze_kernel("mm", store.by_kernel["mm"])
    [f] = desc.formulas
    assert f.kind == T2_LINEAR
    assert f.predict_extents((buf.base, 10, 10)) == [(buf.base, 200)]


def test_t3_strided():
    space = AddressSpace(4096)
    buf = space.malloc(64 << 20)
    store = TraceStore()
    for rows in (4, 8, 16):
        ext = [(buf.base + r * 65536, 1024) for r in range(rows)]
        _record(store, space, "st", (buf.base, rows, 1024, 65536), ext)
    desc = analyze_kernel("st", store.by_kernel["st"])
    [f] = desc.formulas
    assert f.kind == T3_STRIDED
    pred = f.predict_extents((buf.base, 3, 1024, 65536))
    assert pred == [(buf.base + r * 65536, 1024) for r in range(3)]


def test_t3_merged_degenerate_invocation():
    """When stride == chunk size the trace merges to one extent; the fitted
    formula must still verify against it (the dwt2d level-0 case)."""
    space = AddressSpace(4096)
    buf = space.malloc(64 << 20)
    store = TraceStore()
    for rows, size, stride in ((8, 4096, 8192), (16, 2048, 8192), (4, 8192, 8192)):
        ext = [(buf.base + r * stride, size) for r in range(rows)]
        _record(store, space, "st", (buf.base, rows, size, stride), ext)
    desc = analyze_kernel("st", store.by_kernel["st"])
    [f] = desc.formulas
    assert f.kind == T3_STRIDED


def test_indirect_access_is_opaque():
    space = AddressSpace(4096)
    a = space.malloc(1 << 20)
    hidden = space.malloc(1 << 20)
    store = TraceStore()
    for i in range(3):
        _record(
            store,
            space,
            "k",
            (a.base, 5),
            [(a.base, 4096), (hidden.base + 4096 * (i * 7 % 5), 4096)],
        )
    desc = analyze_kernel("k", store.by_kernel["k"])
    assert desc.has_opaque()
    kinds = {f.kind for f in desc.formulas}
    assert T1_FIXED in kinds and OPAQUE in kinds


def _check_linear_recovery(coeff, vals):
    """Any exact size = coeff * arg relationship is recovered and extrapolates."""
    space = AddressSpace(4096)
    buf = space.malloc(coeff * 4096 * 2 + (1 << 20))
    store = TraceStore()
    for v in vals:
        _record(store, space, "k", (buf.base, v), [(buf.base, min(coeff * v, buf.size))])
    # keep within the buffer
    if any(coeff * v > buf.size for v in vals):
        return
    desc = analyze_kernel("k", store.by_kernel["k"])
    [f] = desc.formulas
    unseen = max(vals) + 1
    if coeff * unseen <= buf.size:
        assert f.predict_extents((buf.base, unseen)) == [(buf.base, coeff * unseen)]


if st is not None:

    @settings(max_examples=40, deadline=None)
    @given(
        coeff=st.integers(min_value=1, max_value=64),
        vals=st.lists(
            st.integers(min_value=1, max_value=4096), min_size=3, max_size=6, unique=True
        ),
    )
    def test_property_linear_recovery(coeff, vals):
        _check_linear_recovery(coeff, vals)

else:  # deterministic fallback when hypothesis is unavailable

    @pytest.mark.parametrize("seed", range(40))
    def test_property_linear_recovery(seed):
        rnd = random.Random(2000 + seed)
        coeff = rnd.randint(1, 64)
        vals = rnd.sample(range(1, 4097), rnd.randint(3, 6))
        _check_linear_recovery(coeff, vals)


@pytest.mark.parametrize("seed", range(20))
def test_property_template_never_overpredicts(seed):
    """Strict template matching ⇒ zero false positives on any workload drawn
    from the T1/T2 family (the paper's 0.00% F+ column)."""
    rnd = random.Random(seed)
    space = AddressSpace(4096)
    bufs = [space.malloc(rnd.randrange(1, 64) << 12) for _ in range(4)]
    store = TraceStore()
    cmds = []
    for i in range(6):
        n = rnd.randrange(1, 5)
        b = bufs[rnd.randrange(len(bufs))]
        size = min(n * 4096, b.size)
        cmd = kernel("k", (b.base, n, i), 5.0, [(b.base, size)])
        store.record(cmd, space=space)
        cmds.append(cmd)
    desc = analyze_traces(store)
    stats = evaluate_accuracy(TemplatePredictor(desc), cmds, space)
    assert stats.wrong_pages == 0  # F+ == 0 by construction
