"""Platform presets: the paper testbeds plus the heterogeneous datacenter
variants the cluster scheduler mixes."""
import dataclasses

import pytest

from repro.core.hardware import (
    A100_40G,
    A100_80G,
    H100_80G,
    PLATFORMS,
    RTX3080,
    RTX5080,
    TPU_V5E,
    fault_bandwidth_gbps,
    hbm_variant,
)


def test_all_presets_registered():
    for p in (RTX5080, RTX3080, A100_40G, A100_80G, H100_80G, TPU_V5E):
        assert PLATFORMS[p.name] is p
    assert len({p.name for p in PLATFORMS.values()}) == len(PLATFORMS)


def test_hbm_capacity_classes():
    assert A100_80G.hbm_bytes == 2 * A100_40G.hbm_bytes
    assert A100_40G.hbm_bytes == 40 << 30
    assert H100_80G.hbm_bytes == 80 << 30


def test_variants_differ_in_swap_bandwidth():
    """The point of heterogeneous presets: same fault control plane, visibly
    different migration bandwidths."""
    assert A100_40G.d2h_gbps < A100_80G.d2h_gbps < H100_80G.d2h_gbps
    assert A100_40G.duplex_cap_gbps < A100_80G.duplex_cap_gbps
    assert H100_80G.duplex_cap_gbps > A100_80G.duplex_cap_gbps
    # the control-plane-dominated fault cost is the shared KMD path
    assert A100_40G.fault_total_us == A100_80G.fault_total_us == 31.79


@pytest.mark.parametrize("plat", [A100_40G, A100_80G, H100_80G])
def test_datacenter_presets_sane(plat):
    assert plat.page_size == 4 << 10
    assert 0 < plat.fault_transfer_us < plat.fault_total_us
    # duplex ceiling sits between one-way and the naive two-way sum
    assert plat.d2h_gbps < plat.duplex_cap_gbps < plat.d2h_gbps + plat.h2d_gbps
    # faulting is catastrophically slower than batched DMA (paper §3)
    assert fault_bandwidth_gbps(plat) < plat.h2d_gbps / 10


def test_hbm_variant_helper():
    v = hbm_variant(A100_80G, 24 << 30)
    assert v.hbm_bytes == 24 << 30
    assert v.name == "a100_80g_24g"
    assert v.d2h_gbps == A100_80G.d2h_gbps
    # frozen source untouched
    assert A100_80G.hbm_bytes == 80 << 30
    named = hbm_variant(RTX5080, 8 << 30, name="rtx5080_binned")
    assert named.name == "rtx5080_binned"
    assert dataclasses.replace(named, name=RTX5080.name, hbm_bytes=16 << 30) == RTX5080
