"""Invariant auditor: the read-only cross-checks that prove page
conservation and coherence across pools, cores, directory, topology, and
vault — including that the auditor actually *catches* corruption (each check
is exercised against a deliberately broken structure)."""
import pytest

from repro.core.hbm import HBMPool, HBMPoolPaged
from repro.core.invariants import (
    InvariantAuditor,
    InvariantViolation,
    audit_core,
    audit_pool,
)
from repro.core.scheduler import RoundRobinPolicy
from repro.core.simulator import SimCore, TaskArrival
from repro.core.hardware import RTX5080
from repro.serving import Request, ServedRequestTask

ARCH = "qwen3-1.7b"
PAGE = 1 << 20


def _pool(kind, cap=64):
    pool = HBMPool(cap) if kind == "run" else HBMPoolPaged(cap)
    pool.register_task(1, (0, 32))
    pool.populate_runs([(0, 8), (12, 20)])
    return pool


def _serving_core(name="gpu0", req_id=0, output_tokens=40, cap=4 << 30):
    req = Request(req_id, ARCH, 1_000.0, prompt_tokens=64,
                  output_tokens=output_tokens)
    events = [
        TaskArrival(req.arrival_us, ServedRequestTask(req_id, req, page_size=PAGE))
    ]
    return SimCore(
        [], RTX5080, "msched", capacity_bytes=cap,
        policy=RoundRobinPolicy(350_000.0), task_events=events,
        page_size=PAGE, prepopulate=False, name=name,
        profile_set=[ServedRequestTask(10_000_000 + req_id, req, page_size=PAGE)],
    )


# --------------------------------------------------------------------------
# audit_pool
# --------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["run", "paged"])
def test_healthy_pool_is_clean(kind):
    assert audit_pool(_pool(kind)) == []


def test_catches_count_drift():
    pool = _pool("run")
    pool._count += 3  # simulated double-count
    bad = audit_pool(pool)
    assert any("chain holds" in b for b in bad)


def test_catches_chain_index_divergence():
    pool = _pool("run")
    # surgically unlink the head segment from the LRU chain only: the
    # sorted index still sees it — exactly the split-brain wipe()/fail()
    # could cause if it cleared one view and not the other
    seg = pool._h.nxt
    pool._unlink(seg)
    bad = audit_pool(pool)
    assert any("disagree" in b for b in bad)


def test_catches_orphan_pages_outside_task_spans():
    pool = _pool("run")
    pool.populate_runs([(40, 44)])  # resident but owned by no task
    bad = audit_pool(pool)
    assert any("outside every registered task span" in b for b in bad)
    # paged pool: same contract
    paged = _pool("paged")
    paged.populate_runs([(40, 44)])
    assert any(
        "outside every registered task span" in b for b in audit_pool(paged)
    )


def test_catches_over_capacity_residency():
    pool = _pool("paged")
    pool.register_task(2, (0, 1 << 12))
    for p in range(pool.capacity + 4):  # stuffed past the physical limit
        pool._list[p] = None
    assert any("exceeds capacity" in b for b in audit_pool(pool))


# --------------------------------------------------------------------------
# audit_core
# --------------------------------------------------------------------------


def test_healthy_core_is_clean_mid_run():
    core = _serving_core()
    core.run(50_000.0, final=False)
    assert audit_core(core) == []


def test_failed_core_must_be_quiescent():
    core = _serving_core()
    core.run(50_000.0, final=False)
    core.fail(60_000.0)
    assert audit_core(core) == []
    # residue a buggy teardown could leave behind is flagged
    core.pool.register_task(9, (0, 16))
    core.pool.populate_runs([(0, 4)])
    assert any("resident" in b for b in audit_core(core))


def test_catches_orphaned_linger_flag():
    core = _serving_core()
    core.run(50_000.0, final=False)
    core.lingering.add(999)  # flag with no registered span
    bad = audit_core(core)
    assert any("double-free" in b for b in bad)


def test_catches_stale_warm_runs():
    core = _serving_core()
    core.run(50_000.0, final=False)
    core._warm_runs[12345] = [(0, 4)]  # no such queued task
    assert any("warm runs" in b for b in audit_core(core))


# --------------------------------------------------------------------------
# InvariantAuditor
# --------------------------------------------------------------------------


def test_auditor_raises_with_tagged_location():
    core = _serving_core()
    core.run(50_000.0, final=False)
    auditor = InvariantAuditor([core])
    assert auditor.check(50_000.0, "mid") == []
    core.lingering.add(999)
    with pytest.raises(InvariantViolation) as ei:
        auditor.check(51_000.0, "fault")
    assert "[fault@51000us]" in str(ei.value)
    # InvariantViolation is an AssertionError: plain assertion tooling works
    assert isinstance(ei.value, AssertionError)


def test_auditor_accumulates_when_not_raising():
    core = _serving_core()
    core.run(50_000.0, final=False)
    core.lingering.add(999)
    auditor = InvariantAuditor([core], raise_on_violation=False)
    bad = auditor.check(51_000.0, "tick")
    assert bad and auditor.violations
    assert auditor.checks == 1


def test_auditing_never_mutates_state():
    core = _serving_core()
    core.run(50_000.0, final=False)
    before = (
        core.pool.used,
        list(core.pool.eviction_runs()),
        len(core.records),
        core.t,
    )
    InvariantAuditor([core]).check(core.t, "probe")
    after = (
        core.pool.used,
        list(core.pool.eviction_runs()),
        len(core.records),
        core.t,
    )
    assert before == after
