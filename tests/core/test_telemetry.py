"""Telemetry hub: emission typing, the event cap, the stall-attribution
ledger's conservation law, the telemetry-off bit-for-bit guarantee on the
core simulator (4 backends, static and serving), and the trace exporters'
round-trip through the validator."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core.hardware import RTX5080
from repro.core.scheduler import RoundRobinPolicy
from repro.core.simulator import percentile, simulate
from repro.core.workloads import LLMDecodeTask, MatMulTask
from repro.serving import (
    AlwaysAdmit,
    MSchedAdmission,
    SLOSpec,
    poisson_trace,
    serve_trace,
)
from repro.telemetry import (
    EVENT_TYPES,
    STALL_CATEGORIES,
    LedgerConservationError,
    StallLedger,
    Telemetry,
    chrome_trace,
    validate_trace,
)

ARCH = "qwen3-1.7b"
PAGE = 1 << 20
SLO = SLOSpec(ttft_us=3_000_000.0, tpot_us=100_000.0)


def _progs():
    return [
        LLMDecodeTask(0, page_size=PAGE, max_context=512),
        MatMulTask(1, 2048, page_size=PAGE),
    ]


def _trace(rate=5.0, duration=1.2, seed=7, output_mean=16):
    return poisson_trace(
        rate, duration, seed=seed, tenants=(ARCH,), prompt_mean=64,
        output_mean=output_mean, max_output=2 * output_mean,
    )


def _static(backend, telemetry=None, cap_ratio=1.5):
    progs = _progs()
    foot = sum(p.footprint_bytes() for p in progs)
    q = 2_000.0 if backend in ("um", "suv") else 350_000.0
    return simulate(
        progs, RTX5080, backend, capacity_bytes=int(foot / cap_ratio),
        sim_us=1_000_000.0, policy=RoundRobinPolicy(q), telemetry=telemetry,
    )


def _serve(backend, telemetry=None):
    admission = (
        MSchedAdmission(headroom=0.9) if backend == "msched" else AlwaysAdmit()
    )
    q = 2_000.0 if backend in ("um", "suv") else 350_000.0
    return serve_trace(
        _trace(), RTX5080, backend=backend, capacity_bytes=3 << 30,
        admission=admission, policy=RoundRobinPolicy(q), page_size=PAGE,
        slo=SLO, telemetry=telemetry,
    )


def _rec_tuple(r):
    return (
        r.task_id, r.arrival_us, r.admitted_us, r.first_iter_us,
        r.finished_us, r.iterations_done, r.total_iterations, r.rejected,
    )


def _result_fingerprint(res):
    return (
        res.sim_us, res.faults, res.migrated_bytes, res.switches,
        res.control_us, res.hbm_used_pages, res.hbm_freed_pages,
        tuple(sorted(
            (tid, st.completions, st.commands, st.busy_us)
            for tid, st in res.per_task.items()
        )),
        tuple(_rec_tuple(r) for r in res.requests),
    )


# --------------------------------------------------------------------------
# Hub emission typing + the event cap
# --------------------------------------------------------------------------


def test_emit_rejects_unknown_event_and_phase():
    tel = Telemetry()
    with pytest.raises(ValueError):
        tel.emit("mystery_event", "i", "gpu0", 0.0)
    with pytest.raises(ValueError):
        tel.emit("finish", "Z", "gpu0", 0.0)
    with pytest.raises(ValueError):
        Telemetry(sample_stride=0)


def test_stall_ledger_rejects_unknown_key():
    led = StallLedger()
    with pytest.raises(ValueError):
        led.add(1, "coffee-break", 10.0)
    led.add(1, "fault_service", -5.0)  # non-positive: ignored
    assert led.raw(1) == {}


def test_event_cap_counts_drops_and_exempts_end_events():
    tel = Telemetry(max_events=2)
    tel.begin("switch", "gpu0", 0.0, task_id=1)
    tel.begin("switch", "gpu0", 1.0, task_id=2)
    tel.instant("finish", "gpu0", 2.0, task_id=1)  # over cap: dropped
    tel.end("switch", "gpu0", 3.0, task_id=2)  # "E" exempt
    tel.end("switch", "gpu0", 4.0, task_id=1)
    assert tel.dropped_events == 1
    assert [e.ph for e in tel.events] == ["B", "B", "E", "E"]
    # the capped trace still validates (balanced pairs)
    doc = chrome_trace(tel)
    assert validate_trace(doc) == []
    assert doc["dropped_events"] == 1


def test_event_cap_drops_end_whose_begin_was_dropped():
    """Regression: the E-exemption must not emit an end event whose begin
    was dropped at the cap — the validator would see an unmatched E."""
    tel = Telemetry(max_events=1)
    tel.begin("switch", "gpu0", 0.0, task_id=1)   # admitted
    tel.begin("switch", "gpu0", 1.0, task_id=2)   # over cap: dropped
    tel.end("switch", "gpu0", 2.0, task_id=2)     # its B was dropped: dropped
    tel.end("switch", "gpu0", 3.0, task_id=1)     # E of an admitted B: kept
    assert [e.ph for e in tel.events] == ["B", "E"]
    assert tel.dropped_events == 2
    doc = chrome_trace(tel)
    assert validate_trace(doc) == []
    assert doc["dropped_events"] == 2


def test_counter_only_trace_validates():
    """A hub that only ever saw counter samples (no events) still exports
    a valid trace with its probe series intact."""
    tel = Telemetry(sample_stride=1)
    for t in range(4):
        tel.counter("gpu0", "hbm_used_pages", float(t), t * 10)
    assert not tel.events
    doc = chrome_trace(tel)
    assert validate_trace(doc) == []
    assert ("gpu0/hbm_used_pages" in doc["probes"])
    assert [v for _t, v in doc["probes"]["gpu0/hbm_used_pages"]] == \
        [0.0, 10.0, 20.0, 30.0]


# --------------------------------------------------------------------------
# Conservation law
# --------------------------------------------------------------------------


def test_ledger_conservation_detects_double_counting():
    res = _serve("msched", telemetry=None).result
    led = StallLedger()
    victim = next(
        r.task_id for r in res.requests if r.finished_us is not None
    )
    # attribute more stall than the victim's whole wall time
    wall = next(
        r.finished_us - r.arrival_us
        for r in res.requests if r.task_id == victim
    )
    led.add(victim, "recovery", 10.0 * wall)
    with pytest.raises(LedgerConservationError):
        led.breakdown(res)


@pytest.mark.parametrize("backend", ["um", "msched"])
def test_serving_trace_ledger_conserves(backend):
    """Every finished request's six categories sum exactly to its
    non-compute wall gap, and the residual queue-wait is non-negative."""
    tel = Telemetry(sample_stride=1)
    _serve(backend, telemetry=tel)
    bd = tel.stall_breakdown()
    assert bd, "a drained serving run must resolve ledger rows"
    for tid, row in bd.items():
        attributed = sum(row[cat] for cat in STALL_CATEGORIES)
        assert attributed == pytest.approx(
            row["non_compute_us"], rel=1e-9, abs=1e-6
        )
        assert row["queue-wait"] >= -1e-6
        assert row["wall_us"] == pytest.approx(
            row["compute_us"] + row["non_compute_us"], rel=1e-9, abs=1e-6
        )
    totals = tel.stall_totals()
    assert set(STALL_CATEGORIES) <= set(totals)
    if backend == "um":
        assert totals["fault-service"] > 0.0, "UM must page-fault under 1.5x"


def test_stall_totals_on_empty_hub():
    """A finalized hub with no finished tasks (empty trace) reports an
    all-zero totals dict rather than crashing or omitting categories."""
    tel = Telemetry(sample_stride=1)
    empty = poisson_trace(
        0.0001, 0.0001, seed=1, tenants=(ARCH,), prompt_mean=64,
        output_mean=8, max_output=16,
    )
    assert len(empty) == 0
    serve_trace(
        empty, RTX5080, backend="msched", capacity_bytes=3 << 30,
        admission=MSchedAdmission(headroom=0.9),
        policy=RoundRobinPolicy(350_000.0), page_size=PAGE, slo=SLO,
        telemetry=tel,
    )
    totals = tel.stall_totals()
    assert set(STALL_CATEGORIES) <= set(totals)
    assert all(v == 0.0 for v in totals.values())


def test_unfinalized_hub_raises():
    tel = Telemetry()
    with pytest.raises(RuntimeError):
        tel.stall_breakdown()


# --------------------------------------------------------------------------
# Telemetry-off bit-for-bit equivalence (the pinned guarantee)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["um", "msched", "ideal", "suv"])
def test_static_run_unperturbed_by_tracing(backend):
    off = _static(backend, telemetry=None)
    on = _static(backend, telemetry=Telemetry(sample_stride=1))
    assert _result_fingerprint(off) == _result_fingerprint(on)


@pytest.mark.parametrize("backend", ["um", "msched", "ideal", "suv"])
def test_serving_run_unperturbed_by_tracing(backend):
    off = _serve(backend, telemetry=None)
    on = _serve(backend, telemetry=Telemetry(sample_stride=1))
    assert _result_fingerprint(off.result) == _result_fingerprint(on.result)
    assert off.to_row() == on.to_row()


# --------------------------------------------------------------------------
# Export + validator round-trip
# --------------------------------------------------------------------------


def test_single_core_trace_exports_and_validates(tmp_path):
    tel = Telemetry(sample_stride=1)
    rep = _serve("msched", telemetry=tel)
    assert any(e.name == "switch" for e in tel.events)
    assert any(e.name == "admission" for e in tel.events)
    assert any(e.name == "finish" for e in tel.events)
    assert ("gpu0", "hbm_used_pages") in tel.series

    doc = tel.chrome_trace()
    assert validate_trace(doc) == []
    # JSON round-trip (what write_chrome produces and trace_report reads)
    path = tmp_path / "t.trace"
    tel.write_chrome(path)
    loaded = json.loads(path.read_text())
    assert validate_trace(loaded) == []
    assert loaded["otherData"]["schema"] == "msched-trace-v1"
    tracks = {
        ev["args"]["name"] for ev in loaded["traceEvents"]
        if ev["ph"] == "M"
    }
    assert "gpu0" in tracks
    # summary banked by finalize matches the run
    assert loaded["summary"]["switches"] == rep.result.switches
    assert loaded["summary"]["faults"] == rep.result.faults

    jsonl = tmp_path / "t.jsonl"
    tel.write_jsonl(jsonl)
    lines = [json.loads(ln) for ln in jsonl.read_text().splitlines()]
    assert lines[0]["type"] == "meta"
    kinds = {ln["type"] for ln in lines}
    assert {"meta", "event", "counter", "ledger"} <= kinds


def test_validator_flags_broken_traces():
    assert validate_trace([]) == ["document is not a JSON object"]
    assert validate_trace({}) == ["missing or non-list traceEvents"]
    bad_pair = {
        "traceEvents": [
            {"name": "switch", "ph": "E", "pid": 1, "tid": 0, "ts": 1.0},
        ],
    }
    assert any("without matching B" in e for e in validate_trace(bad_pair))
    non_monotone = {
        "traceEvents": [
            {"name": "finish", "ph": "i", "pid": 1, "tid": 0, "ts": 5.0},
            {"name": "finish", "ph": "i", "pid": 1, "tid": 0, "ts": 1.0},
        ],
    }
    assert any("not monotone" in e for e in validate_trace(non_monotone))
    bad_ledger = {
        "traceEvents": [],
        "stallLedger": {
            "7": {
                "fault-service": 5.0, "migration-wait": 0.0,
                "queue-wait": 0.0, "link-contention": 0.0,
                "recovery": 0.0, "scheduler-control": 0.0,
                "non_compute_us": 1.0,
            }
        },
    }
    assert any("categories sum" in e for e in validate_trace(bad_ledger))


def test_validator_accepts_metadata_without_timestamp():
    """Chrome ``ph: "M"`` metadata events legally carry no ``ts`` — the
    validator must not flag them (regression: they were reported as
    'bad ts None')."""
    doc = {
        "traceEvents": [
            {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
             "args": {"name": "gpu0"}},
            {"name": "finish", "ph": "i", "pid": 1, "tid": 0, "ts": 1.0},
        ],
    }
    assert validate_trace(doc) == []
    # a metadata-only trace (zero-event run) is valid too
    assert validate_trace({"traceEvents": [
        {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
         "args": {"name": "gpu0"}},
    ]}) == []


_TRACE_REPORT = (
    Path(__file__).resolve().parents[2] / "scripts" / "trace_report.py"
)


@pytest.mark.parametrize("doc", [
    [],                                             # bare-array form
    {"traceEvents": []},                            # object form, no events
    {"traceEvents": [                               # metadata-only
        {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
         "args": {"name": "gpu0"}},
    ]},
])
@pytest.mark.parametrize("mode", [[], ["--validate"]])
def test_trace_report_handles_empty_traces(tmp_path, doc, mode):
    """Regression: ``trace_report`` (both modes) used to crash or report
    a zero-event trace as invalid; it must exit 0 and say the trace is
    empty rather than broken."""
    path = tmp_path / "empty.trace"
    path.write_text(json.dumps(doc))
    out = subprocess.run(
        [sys.executable, str(_TRACE_REPORT), str(path), *mode],
        capture_output=True, text=True,
    )
    assert out.returncode == 0, out.stderr
    assert "empty trace" in out.stdout
    assert "TRACE INVALID" not in out.stderr


def test_event_taxonomy_is_closed():
    """Every documented event type round-trips through emit; the taxonomy
    and the stall categories are the public names docs pin."""
    tel = Telemetry()
    for i, name in enumerate(sorted(EVENT_TYPES)):
        tel.instant(name, "gpu0", float(i))
    assert len(tel.events) == len(EVENT_TYPES)
    assert len(STALL_CATEGORIES) == 6


def test_percentile_convention_guard():
    assert percentile([], 99.0) == 0.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 50.0) == 3.0  # nearest-rank floor
    assert percentile([1.0, 2.0, 3.0, 4.0], 99.0) == 4.0
    with pytest.raises(AssertionError):
        percentile([3.0, 1.0], 50.0)  # unsorted sample
    with pytest.raises(AssertionError):
        percentile([1.0], 120.0)
