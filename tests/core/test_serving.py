"""Serving subsystem tests: trace generators, dynamic task lifecycle,
admission control, page reclamation, and static-result preservation."""
import random

import pytest

from repro.core.hardware import RTX5080
from repro.core.hbm import HBMPool
from repro.core.scheduler import PriorityPolicy, RoundRobinPolicy
from repro.core.simulator import TaskArrival, simulate
from repro.core.workloads import (
    LLMDecodeTask,
    MatMulTask,
    TaskProgram,
    VecAddTask,
    combo,
)
from repro.serving import (
    AlwaysAdmit,
    MSchedAdmission,
    Request,
    SLOSpec,
    ServedRequestTask,
    Trace,
    bursty_trace,
    diurnal_trace,
    poisson_trace,
    serve_trace,
)

ARCH = "qwen3-1.7b"


# --------------------------------------------------------------------------
# Arrival-process generators
# --------------------------------------------------------------------------


@pytest.mark.parametrize("gen", [poisson_trace, bursty_trace, diurnal_trace])
def test_generators_deterministic_under_seed(gen):
    a = gen(8.0, 4.0, seed=123)
    b = gen(8.0, 4.0, seed=123)
    assert a.requests == b.requests
    c = gen(8.0, 4.0, seed=124)
    assert c.requests != a.requests


@pytest.mark.parametrize("gen", [poisson_trace, bursty_trace, diurnal_trace])
def test_generators_rate_sanity(gen):
    """Realized mean rate within 25% of the configured rate (law of large
    numbers over a long window; generators are open-loop)."""
    tr = gen(20.0, 30.0, seed=7)
    realized = len(tr) / 30.0
    assert 0.75 * 20.0 <= realized <= 1.25 * 20.0, realized
    assert all(
        tr.requests[i].arrival_us <= tr.requests[i + 1].arrival_us
        for i in range(len(tr.requests) - 1)
    )
    assert all(r.prompt_tokens >= 1 and r.output_tokens >= 1 for r in tr)


def test_bursty_is_burstier_than_poisson():
    """Same mean rate, higher inter-arrival CV."""

    def cv(tr):
        gaps = [
            b.arrival_us - a.arrival_us
            for a, b in zip(tr.requests, tr.requests[1:])
        ]
        mean = sum(gaps) / len(gaps)
        var = sum((g - mean) ** 2 for g in gaps) / len(gaps)
        return var**0.5 / mean

    assert cv(bursty_trace(10.0, 60.0, seed=3, cv=4.0)) > 1.5 * cv(
        poisson_trace(10.0, 60.0, seed=3)
    )


def test_trace_json_roundtrip(tmp_path):
    tr = diurnal_trace(5.0, 10.0, seed=11, amplitude=0.5)
    path = tmp_path / "trace.json"
    tr.save(path)
    back = Trace.load(path)
    assert back.requests == tr.requests
    assert back.meta == tr.meta


# --------------------------------------------------------------------------
# Request lifecycle
# --------------------------------------------------------------------------


def test_served_request_task_lifecycle():
    req = Request(0, ARCH, 0.0, prompt_tokens=512, output_tokens=8)
    task = ServedRequestTask(0, req, page_size=1 << 20)
    assert task.total_iterations == 8
    # per-request KV: sized to the request, not the model max context
    per_tok = task.kv_token_bytes * task.cfg.num_layers
    assert task.kv_bytes() == per_tok * (512 + 8)
    # prefill (iteration 0, long prompt) is costlier than a decode step
    pre = sum(c.latency_us for c in task.iteration(0))
    dec = sum(c.latency_us for c in task.iteration(1))
    assert pre > dec
    # KV free on completion, then full teardown
    foot = task.footprint_bytes()
    freed = task.free_kv()
    assert freed == per_tok * (512 + 8)
    assert task.footprint_bytes() == foot - freed
    task.release()
    assert task.footprint_bytes() == 0


def test_prefill_attention_covers_prompt():
    req = Request(0, ARCH, 0.0, prompt_tokens=64, output_tokens=4)
    task = ServedRequestTask(0, req, page_size=1 << 20)
    attn = [c for c in task.iteration(0) if c.name == "llm_attn"][0]
    kv_ext = attn.true_extents[0]
    assert kv_ext[1] == 64 * task.kv_token_bytes


# --------------------------------------------------------------------------
# Dynamic admission / retirement: no leaks, records complete
# --------------------------------------------------------------------------


class _FiniteVec(VecAddTask):
    def __init__(self, task_id, iters, **kw):
        super().__init__(task_id, **kw)
        self.total_iterations = iters


def _random_events(rnd, n):
    evs = []
    t = 0.0
    for i in range(n):
        t += rnd.expovariate(1 / 400.0)
        evs.append(
            TaskArrival(
                t,
                _FiniteVec(
                    100 + i,
                    iters=rnd.randrange(1, 6),
                    n_bytes=rnd.randrange(1, 4) << 20,
                    kernels_per_iter=rnd.randrange(1, 4),
                    page_size=64 << 10,
                ),
            )
        )
    return evs


@pytest.mark.parametrize("backend", ["msched", "um", "ideal", "suv"])
def test_randomized_dynamic_no_hbm_leak(backend):
    """Tasks arrive, run to completion, retire — every backend must return
    the pool to its (empty) baseline once the population drains."""
    for seed in range(4):
        rnd = random.Random(seed)
        evs = _random_events(rnd, rnd.randrange(3, 9))
        admission = (
            MSchedAdmission(headroom=0.9) if rnd.random() < 0.5 else AlwaysAdmit()
        )
        res = simulate(
            [],
            RTX5080,
            backend,
            capacity_bytes=rnd.randrange(4, 12) << 20,  # force evictions
            sim_us=10_000_000.0,
            policy=RoundRobinPolicy(2_000.0),
            predictor_kind="oracle",
            task_events=evs,
            admission=admission,
            page_size=64 << 10,
            prepopulate=False,
        )
        assert len(res.requests) == len(evs)
        for rec in res.requests:
            assert rec.finished_us is not None, (backend, seed, rec)
            assert rec.iterations_done == rec.total_iterations
            assert rec.admitted_us is not None and not rec.rejected
        # the leak assertion: hbm.used back to (zero) baseline, pages were
        # actually reclaimed through the free path
        assert res.hbm_used_pages == 0, (backend, seed)
        assert res.hbm_freed_pages > 0


def test_hbm_free_task_regression():
    """Direct driver-level regression: task teardown reclaims exactly the
    task's resident pages and hbm.used returns to baseline."""
    pool = HBMPool(64)
    for p in range(10):
        pool.populate(p)
    baseline = pool.used
    pool.register_task(7, (1000, 1100))
    for p in range(1000, 1040):
        pool.populate(p)
    assert pool.used == baseline + 40
    freed = pool.free_task(7)
    assert freed == 40
    assert pool.used == baseline
    assert pool.freed_pages == 40
    assert pool.free_task(7) == 0  # idempotent
    # frees are not evictions
    assert pool.evictions == 0


def test_static_finite_program_terminates_and_retires():
    """A finite-total_iterations program passed *statically* (no task_events)
    must retire at completion, not pin the scheduler in a zero-time spin."""
    prog = _FiniteVec(0, iters=3, n_bytes=1 << 20, page_size=64 << 10)
    res = simulate(
        [prog], RTX5080, "um", capacity_bytes=64 << 20, sim_us=1_000_000.0,
        policy=RoundRobinPolicy(2_000.0), prepopulate=False,
    )
    assert res.per_task[0].completions == 3
    assert res.sim_us < 1_000_000.0  # terminated at drain, not at horizon
    assert res.hbm_used_pages == 0  # retirement reclaimed the pages


def test_mismatched_event_page_size_rejected():
    ev = TaskArrival(0.0, _FiniteVec(5, iters=1, n_bytes=1 << 20, page_size=4096))
    with pytest.raises(ValueError, match="page_size"):
        simulate(
            [], RTX5080, "um", sim_us=1_000.0, task_events=[ev],
            page_size=64 << 10,
        )
    # static programs get the same validation against an explicit page_size
    with pytest.raises(ValueError, match="page_size"):
        simulate(
            [VecAddTask(0, n_bytes=1 << 20, page_size=4096)], RTX5080, "um",
            sim_us=1_000.0, page_size=64 << 10,
        )


def test_empty_iteration_program_fails_loud():
    class _EmptyIter(TaskProgram):
        def iteration(self, it):
            return []

    with pytest.raises(RuntimeError, match="empty command list"):
        simulate(
            [_EmptyIter(0, page_size=4096)], RTX5080, "um",
            capacity_bytes=1 << 20, sim_us=10_000.0,
            policy=RoundRobinPolicy(1_000.0), prepopulate=False,
        )


def test_zero_iteration_task_retires_immediately():
    """A degenerate finite task (total_iterations=0) must not wedge the
    engine: it retires on admission without ever being scheduled."""
    ev = TaskArrival(0.0, _FiniteVec(5, iters=0, n_bytes=1 << 20, page_size=64 << 10))
    work = TaskArrival(
        10.0, _FiniteVec(6, iters=2, n_bytes=1 << 20, page_size=64 << 10)
    )
    res = simulate(
        [], RTX5080, "um", capacity_bytes=64 << 20, sim_us=1_000_000.0,
        policy=RoundRobinPolicy(2_000.0), task_events=[ev, work],
        page_size=64 << 10, prepopulate=False,
    )
    recs = {r.task_id: r for r in res.requests}
    assert recs[5].finished_us is not None and recs[5].iterations_done == 0
    assert recs[6].iterations_done == 2
    assert res.sim_us < 1_000_000.0
    # static flavor of the same degenerate program
    res = simulate(
        [_FiniteVec(0, iters=0, n_bytes=1 << 20, page_size=64 << 10)],
        RTX5080, "um", capacity_bytes=64 << 20, sim_us=1_000_000.0,
        policy=RoundRobinPolicy(2_000.0), prepopulate=False,
    )
    assert res.sim_us == 0.0
    # serving-side validation rejects the request outright
    with pytest.raises(ValueError, match="token counts"):
        ServedRequestTask(0, Request(0, ARCH, 0.0, 8, 0))


def test_colliding_task_ids_rejected():
    static = VecAddTask(3, n_bytes=1 << 20, page_size=64 << 10)
    ev = TaskArrival(0.0, _FiniteVec(3, iters=1, n_bytes=1 << 20, page_size=64 << 10))
    with pytest.raises(ValueError, match="collides"):
        simulate(
            [static], RTX5080, "um", capacity_bytes=64 << 20,
            sim_us=100_000.0, policy=RoundRobinPolicy(2_000.0),
            task_events=[ev],
        )


def test_address_space_release():
    prog = _FiniteVec(3, iters=1, n_bytes=1 << 20, page_size=64 << 10)
    span = prog.space.page_span()
    assert span[1] > span[0]
    released = prog.release()
    assert released == span
    assert prog.footprint_bytes() == 0
    assert prog.space.find_buffer(span[0] * prog.space.page_size) is None


# --------------------------------------------------------------------------
# Admission control
# --------------------------------------------------------------------------


def _tiny_trace(n=6, rate=50.0, seed=5):
    return poisson_trace(
        rate, n / rate, seed=seed, tenants=(ARCH,),
        prompt_mean=64, output_mean=6, max_prompt=128, max_output=12,
    )


def test_msched_admission_queues_under_pressure():
    """With HBM sized for ~one request, the controller serializes admissions
    instead of letting the population thrash; everyone still finishes."""
    tr = _tiny_trace()
    probe = ServedRequestTask(999, tr.requests[0], page_size=1 << 20)
    one = probe.footprint_bytes()
    ctrl = MSchedAdmission(headroom=0.9)
    rep = serve_trace(
        tr, RTX5080, backend="msched", capacity_bytes=int(1.2 * one),
        admission=ctrl, policy=RoundRobinPolicy(100_000.0), page_size=1 << 20,
    )
    assert rep.n_finished == len(tr)
    assert ctrl.queued > 0  # pressure actually exercised the queue path
    assert rep.result.hbm_used_pages == 0


def test_admission_reject_on_deadline():
    tr = _tiny_trace(n=8, rate=100.0)
    ctrl = MSchedAdmission(headroom=0.9, max_wait_us=1_000.0)
    probe = ServedRequestTask(999, tr.requests[0], page_size=1 << 20)
    rep = serve_trace(
        tr, RTX5080, backend="msched",
        capacity_bytes=int(1.2 * probe.footprint_bytes()),
        admission=ctrl, policy=RoundRobinPolicy(100_000.0), page_size=1 << 20,
    )
    assert rep.n_rejected > 0
    assert rep.n_finished + rep.n_rejected == rep.n_requests


def test_request_records_slo_metrics():
    tr = _tiny_trace()
    rep = serve_trace(
        tr, RTX5080, backend="msched", capacity_bytes=RTX5080.hbm_bytes,
        admission=AlwaysAdmit(), policy=RoundRobinPolicy(100_000.0),
        page_size=1 << 20, slo=SLOSpec(ttft_us=1e9, tpot_us=1e9),
    )
    assert rep.n_finished == len(tr)
    for rec in rep.result.finished_requests():
        assert rec.ttft_us() is not None and rec.ttft_us() > 0
        lat = rec.latency_us()
        assert lat is not None and lat >= rec.ttft_us()
        if rec.total_iterations and rec.total_iterations > 1:
            assert rec.tpot_us() is not None and rec.tpot_us() > 0
    # infinitely lax SLOs: goodput == throughput
    assert rep.goodput_per_s == pytest.approx(rep.throughput_per_s)


# --------------------------------------------------------------------------
# Static results preserved bit-for-bit
# --------------------------------------------------------------------------


def _fingerprint(res):
    return (
        res.sim_us,
        res.switches,
        res.faults,
        res.migrated_bytes,
        res.control_us,
        res.total_completions(),
        tuple(
            (tid, s.completions, s.commands, s.busy_us)
            for tid, s in sorted(res.per_task.items())
        ),
    )


def test_static_combo_results_preserved_bit_for_bit():
    """Golden fingerprints recorded on the pre-serving engine (PR 1): the
    dynamic-lifecycle machinery must be invisible when no arrivals are
    configured. Pure-Python float arithmetic is deterministic, so these
    values are exact across platforms."""
    progs = combo("A", page_size=256 << 10, scale=0.05)
    foot = sum(p.footprint_bytes() for p in progs)
    res = simulate(
        progs, RTX5080, "msched", capacity_bytes=int(foot / 1.5),
        sim_us=100_000.0, policy=RoundRobinPolicy(10_000.0),
        predictor_kind="oracle",
    )
    assert _fingerprint(res)[:6] == (
        103033.16203421363, 10, 0, 130809856, 2830.7400000000002, 5973,
    )

    rt = MatMulTask(0, dim=1024, n_matrices=4, page_size=256 << 10)
    be = VecAddTask(1, n_bytes=64 << 20, page_size=256 << 10)
    foot = rt.footprint_bytes() + be.footprint_bytes()
    res = simulate(
        [rt, be], RTX5080, "msched", capacity_bytes=int(foot / 1.5),
        sim_us=600_000, policy=PriorityPolicy(quantum_us=50_000.0),
        arrivals={0: [float(i * 200_000) for i in range(3)]},
        priorities={0: 10, 1: 0},
    )
    assert _fingerprint(res)[:6] == (
        606495.3071845965, 13, 7680, 15858663424, 2486.2400000000002, 13,
    )


def test_empty_event_list_is_static():
    progs = [
        VecAddTask(0, n_bytes=2 << 20, page_size=64 << 10),
        MatMulTask(1, dim=512, n_matrices=4, page_size=64 << 10),
    ]
    foot = sum(p.footprint_bytes() for p in progs)
    kw = dict(
        capacity_bytes=int(foot / 1.5), sim_us=80_000.0,
        predictor_kind="oracle",
    )
    a = simulate(progs, RTX5080, "msched", policy=RoundRobinPolicy(5_000.0), **kw)
    progs2 = [
        VecAddTask(0, n_bytes=2 << 20, page_size=64 << 10),
        MatMulTask(1, dim=512, n_matrices=4, page_size=64 << 10),
    ]
    b = simulate(
        progs2, RTX5080, "msched", policy=RoundRobinPolicy(5_000.0),
        task_events=[], admission=AlwaysAdmit(), **kw
    )
    assert _fingerprint(a) == _fingerprint(b)
    assert b.requests == []


# --------------------------------------------------------------------------
# End-to-end serving comparison (the headline): slow sweep kept out of tier-1
# --------------------------------------------------------------------------


def test_msched_goodput_beats_um_under_oversubscription():
    """Fast version of benchmarks/serve_oversub.py acceptance: ≥1.5×
    oversubscription, MSched goodput ≥ 3× UM on the same seeded trace."""
    tr = poisson_trace(
        4.0, 1.5, seed=7, tenants=(ARCH,), prompt_mean=128,
        output_mean=12, max_prompt=256, max_output=24,
    )
    probe = ServedRequestTask(999, tr.requests[0], page_size=1 << 20)
    cap = int(3 * probe.footprint_bytes() / 1.5)
    slo = SLOSpec(ttft_us=2e6, tpot_us=50e3)
    um = serve_trace(
        tr, RTX5080, backend="um", capacity_bytes=cap,
        admission=AlwaysAdmit(), policy=RoundRobinPolicy(2_000.0),
        page_size=1 << 20, slo=slo,
    )
    ms = serve_trace(
        tr, RTX5080, backend="msched", capacity_bytes=cap,
        admission=MSchedAdmission(headroom=0.9),
        policy=RoundRobinPolicy(350_000.0), page_size=1 << 20, slo=slo,
    )
    assert ms.goodput_per_s > 0
    assert ms.goodput_per_s >= 3.0 * um.goodput_per_s, (
        ms.goodput_per_s, um.goodput_per_s,
    )


@pytest.mark.slow
def test_serve_oversub_benchmark_full():
    from benchmarks.serve_oversub import run_bench

    report = run_bench(out_path=None)
    assert report["meets_target"]
