"""Metrics registry + online prediction audit: the closed metric taxonomy,
the histogram percentile convention pin, the observer-contract bit-for-bit
guarantee with the metrics/audit planes attached (static, serving, cluster,
faulted — all four backends), the online-vs-offline Table 1 reconciliation,
the under-fetch/ledger cross-check, the ``metrics-report-v1`` round-trip and
Prometheus exposition, and the CLI surfaces (``msctl metrics``,
``bench_diff``, ``trace_report --json``)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.cluster import FaultEvent, FaultInjector, homogeneous, simulate_cluster
from repro.core.hardware import NVLINK_A100_GBPS, RTX5080
from repro.core.predictor import TemplatePredictor, evaluate_accuracy
from repro.core.profiler import profile_programs
from repro.core.scheduler import RoundRobinPolicy
from repro.core.simulator import percentile, simulate
from repro.core.templates import analyze_traces
from repro.core.workloads import LLMDecodeTask, MatMulTask, combo
from repro.serving import (
    AlwaysAdmit,
    MSchedAdmission,
    SLOSpec,
    poisson_trace,
    serve_trace,
)
from repro.telemetry import (
    METRIC_TYPES,
    METRICS_SCHEMA,
    STALL_CATEGORIES,
    Histogram,
    MetricsRegistry,
    MetricsReport,
    PredictionAuditor,
    Telemetry,
    validate_trace,
)

ARCH = "qwen3-1.7b"
PAGE = 1 << 20
NV = NVLINK_A100_GBPS
SLO = SLOSpec(ttft_us=3_000_000.0, tpot_us=100_000.0)

_SCRIPTS = Path(__file__).resolve().parents[2] / "scripts"


def _progs():
    return [
        LLMDecodeTask(0, page_size=PAGE, max_context=512),
        MatMulTask(1, 2048, page_size=PAGE),
    ]


def _trace(rate=5.0, duration=1.2, seed=7, output_mean=16):
    return poisson_trace(
        rate, duration, seed=seed, tenants=(ARCH,), prompt_mean=64,
        output_mean=output_mean, max_output=2 * output_mean,
    )


def _static(backend, telemetry=None, cap_ratio=1.5):
    progs = _progs()
    foot = sum(p.footprint_bytes() for p in progs)
    q = 2_000.0 if backend in ("um", "suv") else 350_000.0
    return simulate(
        progs, RTX5080, backend, capacity_bytes=int(foot / cap_ratio),
        sim_us=1_000_000.0, policy=RoundRobinPolicy(q), telemetry=telemetry,
    )


def _serve(backend, telemetry=None):
    admission = (
        MSchedAdmission(headroom=0.9) if backend == "msched" else AlwaysAdmit()
    )
    q = 2_000.0 if backend in ("um", "suv") else 350_000.0
    return serve_trace(
        _trace(), RTX5080, backend=backend, capacity_bytes=3 << 30,
        admission=admission, policy=RoundRobinPolicy(q), page_size=PAGE,
        slo=SLO, telemetry=telemetry,
    )


def _rec_tuple(r):
    return (
        r.task_id, r.arrival_us, r.admitted_us, r.first_iter_us,
        r.finished_us, r.iterations_done, r.total_iterations, r.rejected,
    )


def _result_fingerprint(res):
    return (
        res.sim_us, res.faults, res.migrated_bytes, res.switches,
        res.control_us, res.hbm_used_pages, res.hbm_freed_pages,
        tuple(sorted(
            (tid, st.completions, st.commands, st.busy_us)
            for tid, st in res.per_task.items()
        )),
        tuple(_rec_tuple(r) for r in res.requests),
    )


def _cluster_fingerprint(rep):
    m = rep.merged
    return (
        m.sim_us, m.faults, m.migrated_bytes, m.switches, m.control_us,
        m.hbm_used_pages,
        tuple(_rec_tuple(r) for r in m.requests),
        len(rep.migrations), len(rep.peer_fetches), rep.peer_fetch_bytes,
        rep.faults_applied, len(rep.recoveries), rep.checkpoints,
        rep.shed_requests, rep.lost_requests,
    )


def _full_hub():
    return Telemetry(sample_stride=1, metrics=True, audit=True)


def _cluster(telemetry=None, faults=None):
    return simulate_cluster(
        _trace(rate=6.0, duration=1.5, seed=3, output_mean=24),
        homogeneous(2, RTX5080, capacity_bytes=3 << 30, nvlink_gbps=NV),
        backend="msched", placement="leastloaded",
        admission_factory=lambda i: MSchedAdmission(headroom=0.9),
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE, slo=SLO, faults=faults, telemetry=telemetry,
        rebalance_period_us=400_000.0, rebalance_threshold=0.4,
        drain_factor=20.0,
    )


# --------------------------------------------------------------------------
# Registry typing: the closed taxonomy
# --------------------------------------------------------------------------


def test_registry_rejects_unknown_and_mismatched_names():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.inc("mystery_total", "gpu0")
    with pytest.raises(ValueError):
        reg.gauge("switches_total", "gpu0", 1.0)  # counter name as gauge
    with pytest.raises(ValueError):
        reg.observe("hbm_used_pages", "gpu0", 1.0)  # gauge as histogram
    with pytest.raises(ValueError):
        reg.inc("switch_ctrl_us", "gpu0")  # histogram as counter


def test_registry_counter_is_monotone():
    reg = MetricsRegistry()
    reg.inc("switches_total", "gpu0", 2)
    reg.inc("switches_total", "gpu0")
    assert reg.counter_value("switches_total", "gpu0") == 3
    with pytest.raises(ValueError):
        reg.inc("switches_total", "gpu0", -1)


def test_metric_taxonomy_is_closed_and_total():
    """Every name in METRIC_TYPES is writable through the API of its kind —
    the taxonomy is the complete public surface."""
    reg = MetricsRegistry()
    for name, kind in METRIC_TYPES.items():
        if kind == "counter":
            reg.inc(name, "gpu0", 1)
        elif kind == "gauge":
            reg.gauge(name, "gpu0", 1.0)
        else:
            reg.observe(name, "gpu0", 1.0)
    rep = reg.report()
    assert len(rep.metrics) == len(METRIC_TYPES)


def test_histogram_percentile_matches_repo_convention():
    """Histogram.pct delegates to core.simulator.percentile: identical
    samples give identical p50/p99 (the repo-wide nearest-rank pin)."""
    samples = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0]
    h = Histogram()
    for s in samples:
        h.observe(s)
    ref = sorted(samples)
    for p in (0.0, 50.0, 90.0, 99.0, 100.0):
        assert h.pct(p) == percentile(ref, p)
    assert h.p50() == percentile(ref, 50.0)
    assert h.p99() == percentile(ref, 99.0)
    assert h.count == len(samples)
    assert h.sum == pytest.approx(sum(samples))


def test_metrics_report_requires_registry():
    tel = Telemetry()
    with pytest.raises(RuntimeError):
        tel.metrics_report()


# --------------------------------------------------------------------------
# Observer contract with the metrics + audit planes attached
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["um", "msched", "ideal", "suv"])
def test_static_run_unperturbed_by_metrics_audit(backend):
    off = _static(backend, telemetry=None)
    on = _static(backend, telemetry=_full_hub())
    assert _result_fingerprint(off) == _result_fingerprint(on)


@pytest.mark.parametrize("backend", ["um", "msched", "ideal", "suv"])
def test_serving_run_unperturbed_by_metrics_audit(backend):
    off = _serve(backend, telemetry=None)
    on = _serve(backend, telemetry=_full_hub())
    assert _result_fingerprint(off.result) == _result_fingerprint(on.result)
    assert off.to_row() == on.to_row()


def test_cluster_run_unperturbed_by_metrics_audit():
    off = _cluster(telemetry=None)
    on = _cluster(telemetry=_full_hub())
    assert _cluster_fingerprint(off) == _cluster_fingerprint(on)


def test_faulted_cluster_run_unperturbed_by_metrics_audit():
    def inj():
        return FaultInjector([
            FaultEvent(500_000.0, "gpu_fail", gpu="gpu0"),
            FaultEvent(1_200_000.0, "gpu_recover", gpu="gpu0"),
        ])

    off = _cluster(telemetry=None, faults=inj())
    on = _cluster(telemetry=_full_hub(), faults=inj())
    assert _cluster_fingerprint(off) == _cluster_fingerprint(on)


def test_event_counters_match_run_summary():
    tel = _full_hub()
    res = _static("msched", telemetry=tel)
    reg = tel.metrics
    assert reg.counter_value("switches_total", "gpu0") == res.switches
    assert reg.counter_value("faults_total", "gpu0") == res.faults
    assert reg.histogram("switch_ctrl_us", "gpu0").count == res.switches


# --------------------------------------------------------------------------
# Online audit == offline Table 1 (the paper's accuracy claim, scored live)
# --------------------------------------------------------------------------


def test_online_audit_reconciles_with_offline_table1():
    """Feeding the auditor the exact command stream evaluate_accuracy
    scores gives the same F-/F+ to float precision (pinned at 0.1 pp),
    and template F+ stays 0.00% — the paper's Table 1 claim."""
    for name in ("A", "D"):
        progs = combo(name, page_size=PAGE)
        store = profile_programs(progs, iters=4)
        desc = analyze_traces(store)
        for p in progs:
            cmds = [c for it in (10, 11) for c in p.iteration(it)]
            pred = TemplatePredictor(desc)
            stats = evaluate_accuracy(pred, cmds, p.space)
            aud = PredictionAuditor()
            for c in cmds:
                pred.annotate(c, p.space)
                aud.observe_command("gpu0", c, p.space)
            assert aud.fleet.true == stats.true_pages
            assert aud.fleet.pred == stats.pred_pages
            assert aud.fleet.missed == stats.missed_pages
            assert aud.fleet.wrong == stats.wrong_pages
            assert aud.fleet_fneg_pct() == pytest.approx(
                stats.false_negative_pct, abs=0.1
            )
            assert aud.fleet_fpos_pct() == pytest.approx(
                stats.false_positive_pct, abs=0.1
            )
            assert aud.fleet_fpos_pct() == 0.0  # template never overpredicts


def test_traced_sim_audit_scores_template_live():
    """End-to-end: a traced msched run over a paper combo keeps template
    F+ at 0.00% in the live audit, and the audit block lands in the
    finalized summary."""
    progs = combo("A", page_size=PAGE)
    foot = sum(p.footprint_bytes() for p in progs)
    tel = _full_hub()
    simulate(
        progs, RTX5080, "msched", capacity_bytes=int(foot / 1.3),
        sim_us=1_000_000.0, policy=RoundRobinPolicy(350_000.0),
        telemetry=tel,
    )
    aud = tel.audit
    assert aud.fleet.commands > 0
    assert aud.quanta > 0
    assert aud.fleet_fpos_pct() == 0.0
    health = tel.summary["prediction_audit"]
    assert health["audited_commands"] == aud.fleet.commands
    assert health["false_positive_pct"] == 0.0


def test_nonpredictive_backends_produce_no_audit():
    for backend in ("um", "suv"):
        tel = _full_hub()
        _static(backend, telemetry=tel)
        assert tel.audit.fleet.commands == 0
        assert tel.audit.quanta == 0


def test_underfetch_stalls_reconcile_with_ledger():
    """The audit's under-fetch residue equals the stall ledger's
    fault-service bucket over the same tasks."""
    tel = _full_hub()
    _static("msched", telemetry=tel)
    rec = tel.audit.reconcile_ledger(tel)
    assert rec["audit_underfetch_stall_us"] == pytest.approx(
        rec["ledger_fault_service_us"], abs=1e-6
    )
    assert rec["diff_us"] == pytest.approx(0.0, abs=1e-6)


# --------------------------------------------------------------------------
# MetricsReport artifact: round-trip, schema guard, Prometheus, rollups
# --------------------------------------------------------------------------


def test_metrics_report_roundtrip_and_schema_guard(tmp_path):
    tel = _full_hub()
    _serve("msched", telemetry=tel)
    rep = tel.metrics_report()
    doc = rep.to_json()
    assert doc["schema"] == METRICS_SCHEMA
    back = MetricsReport.from_json(json.loads(json.dumps(doc)))
    assert back.to_json() == doc
    path = tmp_path / "m.json"
    rep.write(path)
    assert MetricsReport.from_json(
        json.loads(path.read_text())
    ).to_json() == doc
    with pytest.raises(ValueError):
        MetricsReport.from_json({"schema": "metrics-report-v999"})


def test_prometheus_exposition_format():
    tel = _full_hub()
    _serve("msched", telemetry=tel)
    text = tel.metrics_report().to_prometheus()
    assert "# TYPE msched_switches_total counter" in text
    assert 'msched_switches_total{track="gpu0"}' in text
    assert "# TYPE msched_switch_ctrl_us histogram" in text
    assert 'le="+Inf"' in text
    assert "msched_switch_ctrl_us_count" in text
    # buckets are cumulative: the +Inf bucket equals _count
    lines = text.splitlines()
    inf = next(
        ln for ln in lines
        if ln.startswith("msched_switch_ctrl_us_bucket")
        and 'le="+Inf"' in ln and 'track="gpu0"' in ln
    )
    count = next(
        ln for ln in lines
        if ln.startswith("msched_switch_ctrl_us_count")
        and 'track="gpu0"' in ln
    )
    assert inf.rsplit(" ", 1)[1] == count.rsplit(" ", 1)[1]


def test_cluster_rollups_bank_per_rebalance_tick():
    tel = _full_hub()
    _cluster(telemetry=tel)
    rep = tel.metrics_report()
    # at least one mid-run tick plus the finalize snapshot
    assert len(rep.rollups) >= 2
    ts = [r["ts_us"] for r in rep.rollups]
    assert ts == sorted(ts)
    assert rep.audit is not None
    assert rep.audit["fleet"]["commands"] == tel.audit.fleet.commands
    # audit gauges are re-exported on the fleet track
    assert ("audit_fneg_page_pct", "fleet") in tel.metrics.gauges


def test_control_plane_reexports_prediction_health():
    from repro.control import ControlPlane

    control = ControlPlane(recovery="journal")
    tel = _full_hub()
    simulate_cluster(
        _trace(), homogeneous(2, RTX5080, capacity_bytes=3 << 30,
                              nvlink_gbps=NV),
        backend="msched", placement="leastloaded",
        admission_factory=lambda i: MSchedAdmission(headroom=0.9),
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE, control=control, telemetry=tel, drain_factor=20.0,
    )
    health = control.prediction_health()
    assert health is not None
    assert health["audited_commands"] == tel.audit.fleet.commands
    assert health["false_positive_pct"] == 0.0
    # untraced control plane has no health to report
    assert ControlPlane(recovery="journal").prediction_health() is None


# --------------------------------------------------------------------------
# CLI surfaces: msctl metrics, bench_diff, trace_report --json
# --------------------------------------------------------------------------


def _run_cli(script, *args):
    return subprocess.run(
        [sys.executable, str(_SCRIPTS / script), *map(str, args)],
        capture_output=True, text=True,
    )


def test_msctl_metrics_pretty_prints_and_exposes_prom(tmp_path):
    tel = _full_hub()
    _serve("msched", telemetry=tel)
    path = tmp_path / "m.json"
    tel.metrics_report().write(path)
    out = _run_cli("msctl.py", "metrics", path)
    assert out.returncode == 0, out.stderr
    assert "schema: metrics-report-v1" in out.stdout
    assert "switches_total" in out.stdout
    assert "prediction audit" in out.stdout
    prom = _run_cli("msctl.py", "metrics", path, "--prom")
    assert prom.returncode == 0, prom.stderr
    assert "# TYPE msched_switches_total counter" in prom.stdout


def test_bench_diff_passes_self_and_fails_injected_regression(tmp_path):
    baseline = {
        "benchmark": "x", "seed": 1, "oversubscription": 1.5,
        "goodput_per_s": 100.0, "wall_s": 3.0, "meets_target": True,
    }
    base = tmp_path / "base.json"
    base.write_text(json.dumps(baseline))
    same = tmp_path / "same.json"
    # wall-clock drift alone never fails the gate
    same.write_text(json.dumps(dict(baseline, wall_s=99.0)))
    assert _run_cli("bench_diff.py", base, same).returncode == 0

    flipped = tmp_path / "flipped.json"
    flipped.write_text(json.dumps(dict(baseline, meets_target=False)))
    out = _run_cli("bench_diff.py", base, flipped)
    assert out.returncode == 1
    assert "GATE meets_target" in out.stdout

    # numeric drift beyond tolerance on a config-matched row fails too
    drifted = tmp_path / "drifted.json"
    drifted.write_text(json.dumps(dict(baseline, goodput_per_s=80.0)))
    out = _run_cli("bench_diff.py", base, drifted)
    assert out.returncode == 1
    assert "goodput_per_s" in out.stdout

    # a config mismatch suppresses the numeric check (gates still compared)
    other_cfg = tmp_path / "other.json"
    other_cfg.write_text(
        json.dumps(dict(baseline, seed=2, goodput_per_s=1.0))
    )
    assert _run_cli("bench_diff.py", base, other_cfg).returncode == 0


def test_bench_diff_accepts_committed_artifacts_as_their_own_baseline():
    repo = _SCRIPTS.parent
    pairs = []
    for name in ("BENCH_serving.json", "BENCH_sim_throughput.json"):
        pairs += [repo / name, repo / name]
    out = _run_cli("bench_diff.py", *pairs)
    assert out.returncode == 0, out.stdout + out.stderr


def test_trace_report_json_roundtrip(tmp_path):
    tel = _full_hub()
    _serve("msched", telemetry=tel)
    path = tmp_path / "t.trace"
    tel.write_chrome(path)
    out = _run_cli("trace_report.py", path, "--json")
    assert out.returncode == 0, out.stderr
    doc = json.loads(out.stdout)
    assert doc["schema"] == "msched-trace-v1"
    assert not doc["empty"]
    assert {r["category"] for r in doc["stalls"]["top_sources"]} <= set(
        STALL_CATEGORIES
    )
    assert doc["stalls"]["tasks"] == len(tel.stall_breakdown())
    assert doc["coalescing"]["planned_migrations"] > 0
    assert doc["coalescing"]["pages_per_migration"] > 0
    assert doc["summary"]["switches"] == tel.summary["switches"]
    # the audit block rides in the summary
    assert doc["summary"]["prediction_audit"]["false_positive_pct"] == 0.0
