"""Policy behavior under a dynamic task population: non-runnable tasks must
be skipped everywhere (next_entry AND timeline), queued tasks keep their
rotation slot, and departed tasks are purged."""
from repro.core.scheduler import PriorityPolicy, RoundRobinPolicy, SchedTask


def _tasks(spec):
    """spec: {tid: (priority, runnable)}"""
    return {
        tid: SchedTask(tid, priority=pr, runnable=run)
        for tid, (pr, run) in spec.items()
    }


def test_rr_skips_non_runnable_everywhere():
    pol = RoundRobinPolicy(10.0)
    tasks = _tasks({0: (0, True), 1: (0, False), 2: (0, True)})
    seen = [pol.next_entry(tasks).task_id for _ in range(4)]
    assert 1 not in seen
    assert seen == [0, 2, 0, 2]
    tl = pol.timeline(tasks)
    assert tl.entries and 1 not in tl.task_ids()


def test_rr_all_blocked_yields_none_and_empty_timeline():
    pol = RoundRobinPolicy(10.0)
    tasks = _tasks({0: (0, False), 1: (0, False)})
    assert pol.next_entry(tasks) is None
    assert pol.timeline(tasks).entries == []


def test_rr_blocked_task_keeps_rotation_slot():
    """A queued-but-not-admitted task must not be pushed to the back of the
    rotation while it waits: it runs immediately once runnable."""
    pol = RoundRobinPolicy(10.0)
    run_all = _tasks({0: (0, True), 1: (0, True), 2: (0, True)})
    assert pol.next_entry(run_all).task_id == 0  # rotation now 1,2,0
    blocked = _tasks({0: (0, True), 1: (0, False), 2: (0, True)})
    assert pol.next_entry(blocked).task_id == 2  # 1 skipped, not purged
    unblocked = _tasks({0: (0, True), 1: (0, True), 2: (0, True)})
    assert pol.next_entry(unblocked).task_id == 1  # still ahead of 0


def test_rr_departed_tasks_purged_new_tasks_enrolled():
    pol = RoundRobinPolicy(10.0)
    pol.next_entry(_tasks({0: (0, True), 1: (0, True)}))
    # task 0 departs; task 5 arrives
    tasks = _tasks({1: (0, True), 5: (0, True)})
    order = [pol.next_entry(tasks).task_id for _ in range(4)]
    assert order == [1, 5, 1, 5]
    assert 0 not in pol.timeline(tasks).task_ids()


def test_priority_skips_non_runnable_rt():
    pol = PriorityPolicy(quantum_us=10.0, rt_quantum_us=5.0)
    tasks = _tasks({0: (5, False), 1: (5, True), 2: (0, True)})
    assert pol.next_entry(tasks).task_id == 1
    tl = pol.timeline(tasks)
    assert 0 not in tl.task_ids()
    # RT fully blocked -> BE runs; blocked RT still absent from the timeline
    tasks = _tasks({0: (5, False), 2: (0, True)})
    assert pol.next_entry(tasks).task_id == 2
    assert 0 not in pol.timeline(tasks).task_ids()


def test_priority_be_rotation_survives_blocked_spell():
    pol = PriorityPolicy(quantum_us=10.0)
    run_all = _tasks({0: (0, True), 1: (0, True), 2: (0, True)})
    assert pol.next_entry(run_all).task_id == 0
    blocked = _tasks({0: (0, True), 1: (0, False), 2: (0, True)})
    assert pol.next_entry(blocked).task_id == 2
    assert pol.next_entry(run_all).task_id == 1  # slot preserved


def test_priority_everything_blocked():
    pol = PriorityPolicy()
    tasks = _tasks({0: (5, False), 1: (0, False)})
    assert pol.next_entry(tasks) is None
    assert pol.timeline(tasks).entries == []
