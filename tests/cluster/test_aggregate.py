"""Cluster aggregation: record merging, stat summing, percentile parity."""
import pytest

from repro.cluster.aggregate import (
    RequestStats,
    merge_request_records,
    merge_sim_results,
    peak_concurrent_bytes,
    percentile,
)
from repro.core.simulator import RequestRecord, SimResult, TaskStats


def _rec(tid, arrival, admitted=None, first=None, finished=None, done=0,
         total=None, rejected=False, **meta):
    return RequestRecord(
        tid, arrival, admitted_us=admitted, first_iter_us=first,
        finished_us=finished, iterations_done=done, total_iterations=total,
        rejected=rejected, meta=dict(meta),
    )


def test_percentile_matches_simresult_convention():
    recs = [
        _rec(i, 0.0, admitted=0.0, first=10.0 * (i + 1), finished=100.0 + i,
             total=5, done=5)
        for i in range(7)
    ]
    res = SimResult(1000.0, {}, 0, 0, 0, 0.0, requests=recs)
    for metric in ("ttft", "tpot", "latency"):
        xs = sorted(res.request_metric_us(metric))
        for pct in (50.0, 90.0, 99.0):
            assert percentile(xs, pct) == res.request_percentile_us(metric, pct)
    assert percentile([], 50.0) == 0.0


def test_merge_passthrough_and_order():
    a = [_rec(1, 0.0, finished=5.0), _rec(2, 1.0)]
    b = [_rec(3, 0.5, finished=9.0)]
    merged = merge_request_records([a, b])
    assert [r.task_id for r in merged] == [1, 2, 3]
    assert merged[0] is a[0]  # single-fragment records pass through


def test_merge_migrated_fragments():
    # source fragment: arrived at 0, ran 3/10 iterations, ejected (unfinished)
    src = _rec(7, 0.0, admitted=10.0, first=20.0, done=3, total=10,
               tenant="m", ejected_us=40.0)
    # target fragment: continuation arrived at 50 with the remaining 7 iters
    dst = _rec(7, 50.0, admitted=55.0, first=60.0, finished=100.0, done=7,
               total=7, migrated_from="gpu0")
    (m,) = merge_request_records([[src], [dst]])
    assert m.arrival_us == 0.0
    assert m.admitted_us == 10.0
    assert m.first_iter_us == 20.0  # TTFT measured from the original arrival
    assert m.finished_us == 100.0
    assert m.iterations_done == 10
    assert m.total_iterations == 10  # the source carries the full count
    assert m.meta["fragments"] == 2
    assert m.meta["tenant"] == "m"
    assert m.ttft_us() == 20.0
    assert m.latency_us() == 100.0
    assert m.tpot_us() == pytest.approx((100.0 - 20.0) / 9)


def test_merge_rerouted_fragment_never_admitted_on_source():
    src = _rec(3, 5.0, rerouted_us=30.0)  # queued then stolen: no admission
    dst = _rec(3, 30.0, admitted=31.0, first=40.0, finished=80.0, done=4,
               total=4)
    (m,) = merge_request_records([[src], [dst]])
    assert m.arrival_us == 5.0 and m.admitted_us == 31.0
    assert m.finished_us == 80.0 and m.total_iterations == 4


def test_merge_sim_results_sums_and_maxes():
    a = SimResult(
        100.0,
        {1: TaskStats(2, 10, 50.0, [1.0]), 2: TaskStats(1, 5, 20.0, [])},
        faults=3, migrated_bytes=100, switches=7, control_us=1.5,
        requests=[_rec(1, 0.0, finished=90.0)],
        hbm_used_pages=10, hbm_freed_pages=4,
    )
    b = SimResult(
        250.0,
        {2: TaskStats(4, 9, 30.0, [2.0, 3.0]), 5: TaskStats(1, 1, 1.0, [])},
        faults=1, migrated_bytes=50, switches=2, control_us=0.5,
        requests=[_rec(5, 1.0, finished=200.0)],
        hbm_used_pages=1, hbm_freed_pages=2,
    )
    m = merge_sim_results([a, b])
    assert m.sim_us == 250.0
    assert m.faults == 4 and m.migrated_bytes == 150
    assert m.switches == 9 and m.control_us == 2.0
    assert m.hbm_used_pages == 11 and m.hbm_freed_pages == 6
    assert m.per_task[2].completions == 5
    assert m.per_task[2].commands == 14
    assert m.per_task[2].busy_us == 50.0
    assert m.per_task[2].latencies_us == [2.0, 3.0]
    assert m.per_task[1].completions == 2 and m.per_task[5].completions == 1
    # inputs not mutated by the stat merge
    assert a.per_task[2].completions == 1
    assert [r.task_id for r in m.requests] == [1, 5]


def test_request_stats_scoreboard():
    recs = [
        _rec(0, 0.0, admitted=0.0, first=100.0, finished=300.0, done=3, total=3),
        _rec(1, 0.0, admitted=0.0, first=5_000.0, finished=9_000.0, done=2, total=2),
        _rec(2, 0.0, rejected=True),
        _rec(3, 0.0),  # never finished
    ]
    st = RequestStats.from_records(recs, ttft_slo_us=1_000.0,
                                   tpot_slo_us=None, window_us=1_000_000.0)
    assert st.n_requests == 4 and st.n_finished == 2 and st.n_rejected == 1
    assert st.goodput_per_s == pytest.approx(1.0)  # only record 0 met TTFT
    assert st.throughput_per_s == pytest.approx(2.0)
    assert st.ttft_p50_us == 5_000.0  # [100, 5000] -> index 1
    assert st.latency_p99_us == 9_000.0


def test_peak_concurrent_bytes():
    foot = {1: 100, 2: 50, 3: 70}
    recs = [
        _rec(1, 0.0, admitted=0.0, finished=10.0),
        _rec(2, 0.0, admitted=5.0, finished=20.0),  # overlaps 1 and 3
        _rec(3, 0.0, admitted=12.0, finished=30.0),
        _rec(4, 0.0),  # never admitted: no contribution
    ]
    assert peak_concurrent_bytes(foot, recs) == 150.0
