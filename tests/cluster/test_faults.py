"""Fault injection and recovery: the zero-fault bit-for-bit pin, GPU
fail/recover lifecycles, checkpoint vs. linger vs. cold recovery sources,
link flaps, task crashes, graceful degradation ordering, the retry-exhaustion
accounting, the linger-lifecycle regression, and a seeded chaos sweep under
the inline invariant auditor."""
import math

import pytest

from repro.cluster import (
    CheckpointVault,
    FaultEvent,
    FaultInjector,
    FaultRuntime,
    PeerPrefetchFabric,
    PlacementPolicy,
    Rebalancer,
    homogeneous,
    simulate_cluster,
)
from repro.cluster.topology import HOST
from repro.core.hardware import NVLINK_A100_GBPS, RTX5080
from repro.core.invariants import InvariantAuditor
from repro.core.scheduler import RoundRobinPolicy
from repro.core.simulator import AdmissionController, SimCore, TaskArrival
from repro.serving import (
    MSchedAdmission,
    Request,
    ServedRequestTask,
    Trace,
    poisson_trace,
)

ARCH = "qwen3-1.7b"
PAGE = 1 << 20
NV = NVLINK_A100_GBPS


def _trace(rate=6.0, duration=1.5, seed=3, output_mean=24, rt_fraction=0.0):
    return poisson_trace(
        rate, duration, seed=seed, tenants=(ARCH,), prompt_mean=64,
        output_mean=output_mean, max_output=2 * output_mean,
        rt_fraction=rt_fraction,
    )


def _rec_tuple(r):
    return (
        r.task_id, r.arrival_us, r.admitted_us, r.first_iter_us,
        r.finished_us, r.iterations_done, r.total_iterations, r.rejected,
    )


class Pin0(PlacementPolicy):
    name = "pin0"

    def place(self, prog, arrival_us, cores):
        return 0


def _serving_core(name, req_id=0, output_tokens=400, cap=4 << 30,
                  slo_class="be"):
    req = Request(req_id, ARCH, 1_000.0, prompt_tokens=64,
                  output_tokens=output_tokens, slo_class=slo_class)
    events = [
        TaskArrival(req.arrival_us, ServedRequestTask(req_id, req, page_size=PAGE))
    ]
    return SimCore(
        [], RTX5080, "msched", capacity_bytes=cap,
        policy=RoundRobinPolicy(350_000.0), task_events=events,
        page_size=PAGE, prepopulate=False, name=name,
        profile_set=[ServedRequestTask(10_000_000 + req_id, req, page_size=PAGE)],
    )


def _runtime(events, topo, cores, fabric=None, vault=None, **kw):
    frt = FaultRuntime(
        FaultInjector(events), topo, cores, Pin0(), fabric=fabric,
        vault=vault, **kw
    )
    return frt


# --------------------------------------------------------------------------
# event / injector basics
# --------------------------------------------------------------------------


def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(0.0, "meteor_strike")
    with pytest.raises(ValueError):
        FaultEvent(0.0, "gpu_fail")  # no gpu named
    with pytest.raises(ValueError):
        FaultEvent(0.0, "link_degrade")  # no link endpoints
    with pytest.raises(ValueError):
        FaultEvent(0.0, "link_degrade", link=("a", "b"), factor=1.5)
    FaultEvent(0.0, "link_degrade", link=("a", "b"), factor=0.0)  # edge down ok


def test_random_schedule_is_seeded_and_ordered():
    topo = homogeneous(3, RTX5080, capacity_bytes=4 << 30, nvlink_gbps=NV)
    a = FaultInjector.random(topo, 3_000_000.0, seed=7, gpu_mtbf_us=500_000.0,
                             link_mtbf_us=700_000.0, crash_mtbf_us=900_000.0)
    b = FaultInjector.random(topo, 3_000_000.0, seed=7, gpu_mtbf_us=500_000.0,
                             link_mtbf_us=700_000.0, crash_mtbf_us=900_000.0)
    assert [(e.time_us, e.kind, e.gpu, e.link) for e in a.events] == [
        (e.time_us, e.kind, e.gpu, e.link) for e in b.events
    ]
    assert a.events  # the rates above must actually produce faults
    times = [e.time_us for e in a.events]
    assert times == sorted(times)
    # every fail is paired with a recover for the same device
    fails = sum(1 for e in a.events if e.kind == "gpu_fail")
    recovers = sum(1 for e in a.events if e.kind == "gpu_recover")
    assert fails == recovers
    # disabled fault classes stay disabled
    quiet = FaultInjector.random(topo, 3_000_000.0, seed=7)
    assert quiet.empty


# --------------------------------------------------------------------------
# the zero-fault equivalence pin (satellite: bit-for-bit guarantee)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["um", "msched", "ideal", "suv"])
@pytest.mark.parametrize("pool", ["run", "paged"])
def test_empty_injector_is_bit_for_bit_free(backend, pool):
    """``faults=FaultInjector.none()`` constructs no fault machinery: the
    run is bit-for-bit the plain composition, every backend, both pools."""
    kw = dict(
        backend=backend, placement="roundrobin",
        policy_factory=lambda i: RoundRobinPolicy(
            2_000.0 if backend == "um" else 350_000.0
        ),
        page_size=PAGE, pool=pool,
    )
    tr = _trace(rate=3.0, duration=0.8)
    plain = simulate_cluster(
        tr, homogeneous(2, RTX5080, capacity_bytes=3 << 30), **kw
    )
    pinned = simulate_cluster(
        _trace(rate=3.0, duration=0.8),
        homogeneous(2, RTX5080, capacity_bytes=3 << 30),
        faults=FaultInjector.none(), **kw
    )
    a, b = plain.merged, pinned.merged
    assert a.sim_us == b.sim_us
    assert a.switches == b.switches
    assert a.faults == b.faults
    assert a.migrated_bytes == b.migrated_bytes
    assert [_rec_tuple(r) for r in a.requests] == [
        _rec_tuple(r) for r in b.requests
    ]
    assert pinned.faults_applied == 0 and not pinned.recoveries


# --------------------------------------------------------------------------
# GPU fail / recover lifecycle (engine-level)
# --------------------------------------------------------------------------


def test_gpu_failure_recovers_and_finishes_everything():
    """gpu0 dies mid-trace and comes back: victims are re-placed on gpu1,
    arrivals during the outage avoid the corpse, and — with a generous
    drain — every request still ends finished, audited at every boundary."""
    inj = FaultInjector([
        FaultEvent(700_000.0, "gpu_fail", gpu="gpu0"),
        FaultEvent(1_500_000.0, "gpu_recover", gpu="gpu0"),
    ])
    rep = simulate_cluster(
        _trace(rate=2.0, duration=1.5, output_mean=200),
        homogeneous(2, RTX5080, capacity_bytes=4 << 30, nvlink_gbps=NV),
        backend="msched", placement=Pin0(),
        admission_factory=lambda i: MSchedAdmission(headroom=0.9),
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE, faults=inj, audit=True, drain_factor=20.0,
    )
    assert rep.faults_applied == 2
    assert rep.recoveries, "running victims must be re-placed"
    assert all(ev.src == "gpu0" for ev in rep.recoveries)
    assert rep.stats.n_finished == rep.stats.n_requests
    assert rep.lost_requests == 0
    assert rep.merged.hbm_used_pages == 0
    # the outage is visible in the records it interrupted
    failed_frags = [
        r for g in rep.per_gpu for r in g.result.requests
        if "failed_us" in r.meta
    ]
    assert failed_frags


def test_whole_fleet_down_holds_then_flushes():
    """Both GPUs dead: arrivals during the blackout are held (placement
    never sees a corpse), then flushed when a device returns."""
    inj = FaultInjector([
        FaultEvent(100_000.0, "gpu_fail", gpu="gpu0"),
        FaultEvent(100_000.0, "gpu_fail", gpu="gpu1"),
        FaultEvent(700_000.0, "gpu_recover", gpu="gpu1"),
    ])
    rep = simulate_cluster(
        _trace(rate=6.0, duration=0.6, output_mean=64),
        homogeneous(2, RTX5080, capacity_bytes=4 << 30),
        backend="msched", placement="leastloaded",
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE, faults=inj, audit=True, drain_factor=30.0,
    )
    assert rep.stats.n_finished == rep.stats.n_requests
    assert rep.lost_requests == 0
    # everything ran on the survivor
    assert rep.per_gpu[1].result.total_completions() > 0
    redisp = [
        r for g in rep.per_gpu for r in g.result.requests
        if "redispatched_from" in r.meta or "recovered_from" in r.meta
    ]
    assert redisp


def test_fleet_never_recovering_accounts_lost_work():
    """The fleet dies and stays dead: interrupted work is accounted as
    rejected — never silently dropped — and every request has a record."""
    tr = _trace(rate=6.0, duration=0.6, output_mean=16)
    inj = FaultInjector([
        FaultEvent(150_000.0, "gpu_fail", gpu="gpu0"),
        FaultEvent(150_000.0, "gpu_fail", gpu="gpu1"),
    ])
    rep = simulate_cluster(
        tr, homogeneous(2, RTX5080, capacity_bytes=4 << 30),
        backend="msched", placement="leastloaded",
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE, faults=inj, audit=True,
    )
    assert rep.lost_requests > 0
    assert {r.task_id for r in rep.merged.requests} == {
        r.req_id for r in tr
    }
    unresolved = [
        r for r in rep.merged.requests
        if r.finished_us is None and not r.rejected
    ]
    assert not unresolved
    assert any(r.meta.get("lost") for r in rep.merged.requests)


# --------------------------------------------------------------------------
# recovery sources: checkpoint > linger > cold
# --------------------------------------------------------------------------


def test_checkpoint_recovery_preserves_progress():
    """With a vault, a GPU failure restores the victim from its newest
    landed snapshot: the completed-iteration prefix is NOT replayed."""
    # one multi-quantum request (snapshots only see tasks still running at
    # a timeslice boundary) pinned to the GPU that will die mid-decode
    tr = Trace([
        Request(0, ARCH, 50_000.0, prompt_tokens=64, output_tokens=600),
    ])
    inj = FaultInjector([FaultEvent(600_000.0, "gpu_fail", gpu="gpu0")])
    rep = simulate_cluster(
        tr, homogeneous(2, RTX5080, capacity_bytes=4 << 30, nvlink_gbps=NV),
        backend="msched", placement=Pin0(),
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE, faults=inj, recovery="checkpoint",
        checkpoint_period_us=100_000.0, audit=True, drain_factor=80.0,
    )
    assert rep.checkpoints > 0 and rep.checkpoint_bytes > 0
    cks = [ev for ev in rep.recoveries if ev.kind == "checkpoint"]
    assert cks, f"expected checkpoint recoveries, got {rep.recoveries}"
    assert cks[0].completed > 0, "progress must be preserved"
    assert cks[0].dst == "gpu1"
    assert rep.stats.n_finished == rep.stats.n_requests
    # the restored continuation resumes at the snapshot's iteration count:
    # across fragments the request replays only the post-snapshot suffix
    frags = [
        r for g in rep.per_gpu for r in g.result.requests if r.task_id == 0
    ]
    done = sum(r.iterations_done for r in frags)
    lost_at_fail = next(
        r.iterations_done for r in frags if "failed_us" in r.meta
    )
    assert done == 600 + (lost_at_fail - cks[0].completed)
    assert done < 600 + lost_at_fail, "checkpoint restore must not full-replay"


def test_linger_recovery_lands_on_the_holding_gpu():
    """A lazily-migrated task dies with its working set still lingering on
    the source peer (the NVLink edge went down right after the move, so the
    continuation's fetches fell back to host and never consumed the copy):
    recovery harvests the copy and re-places the task on the holder —
    instantly, no host round-trip."""
    topo = homogeneous(2, RTX5080, capacity_bytes=4 << 30, nvlink_gbps=NV)
    g0 = _serving_core("gpu0", req_id=0, output_tokens=300)
    g1 = _serving_core("gpu1", req_id=1, output_tokens=2)
    fabric = PeerPrefetchFabric(topo, [g0, g1])
    fabric.wire()
    rb = Rebalancer(topo, prefetch=fabric)
    rb.attach([g0, g1])
    g0.run(200_000.0, final=False)
    mv = rb._move_one(g0, g1, 200_000.0)
    assert mv is not None and mv.kind == "p2p"
    assert fabric.directory.get(0) is not None and g0.pool.used > 0
    # the NVLink edge dies before the continuation's first switch: fetches
    # fall back to host, the linger copy survives on gpu0 untouched
    topo.degrade("gpu0", "gpu1", 0.0)
    # the continuation lands and runs on gpu1 — then gpu1 dies too
    g1.run(mv.arrival_us + 50_000.0, final=False)
    assert 0 in g1.tasks
    assert fabric.directory.get(0) is not None  # copy still on the holder
    t_fail = g1.t
    frt = _runtime(
        [FaultEvent(t_fail, "gpu_fail", gpu="gpu1")], topo, [g0, g1],
        fabric=fabric, recovery="linger",
    )
    frt.apply_due(t_fail)
    lingers = [ev for ev in frt.recoveries if ev.kind == "linger"]
    assert lingers and lingers[0].dst == "gpu0"
    # harvested: no directory entry, no linger flag — admission re-owns
    assert fabric.directory.get(0) is None
    assert 0 not in g0.lingering
    InvariantAuditor([g0, g1], topology=topo, fabric=fabric).check(
        t_fail, "post-fail"
    )
    g0.run(60_000_000.0, final=True)
    frags = [r for r in g0.records + g1.records if r.task_id == 0]
    assert any(r.finished_us is not None for r in frags)


def test_cold_restart_replays_from_scratch():
    """``recovery="cold"`` ignores durable sources: the victim restarts at
    iteration 0 and the lost progress is the recovery event's replay cost."""
    topo = homogeneous(2, RTX5080, capacity_bytes=4 << 30)
    g0 = _serving_core("gpu0", req_id=0, output_tokens=200)
    g1 = _serving_core("gpu1", req_id=1, output_tokens=2)
    g0.run(300_000.0, final=False)
    done_before = g0.tasks[0].stats.completions
    assert done_before > 0
    frt = _runtime(
        [FaultEvent(g0.t, "gpu_fail", gpu="gpu0")], topo, [g0, g1],
        recovery="cold",
    )
    frt.apply_due(g0.t)
    colds = [ev for ev in frt.recoveries if ev.kind == "cold"]
    assert colds and colds[0].replayed_iters == done_before
    assert colds[0].dst == "gpu1"
    g1.run(60_000_000.0, final=True)
    frags = [r for r in g0.records + g1.records if r.task_id == 0]
    assert sum(r.iterations_done for r in frags) == 200 + done_before
    assert any(r.finished_us is not None for r in frags)


def test_denied_restore_backs_off_then_degrades():
    """A checkpoint restore denied by a saturated staging budget requeues
    with growing capped backoff; once the retry budget is spent the victim
    degrades to a cold restart instead of spinning forever."""
    # host DRAM too small for any restore leg: every plan_restore denies
    topo = homogeneous(2, RTX5080, capacity_bytes=4 << 30,
                       host_dram_bytes=PAGE // 2)
    g0 = _serving_core("gpu0", req_id=0, output_tokens=300)
    g1 = _serving_core("gpu1", req_id=1, output_tokens=2)
    vault = CheckpointVault(topo, PAGE)
    g0.run(250_000.0, final=False)
    vault.snapshot([g0, g1], g0.t)
    assert vault.taken >= 1
    # fail only after the snapshot's D2H leg lands (an unlanded checkpoint
    # is not restorable and recovery would degrade straight to cold)
    t0 = vault._by_task[0][-1].ready_us + 1_000.0
    frt = _runtime(
        [FaultEvent(t0, "gpu_fail", gpu="gpu0")], topo, [g0, g1],
        vault=vault, recovery="checkpoint",
        backoff_us=10_000.0, backoff_cap_us=40_000.0,
        max_recovery_retries=3,
    )
    t = t0
    while frt.next_time() < float("inf"):
        t = max(t, frt.next_time())
        frt.apply_due(t)
    requeues = [ev for ev in frt.recoveries if ev.kind == "requeue"]
    assert len(requeues) == 3
    # capped exponential: 10ms, 20ms, then the 40ms cap
    gaps = [ev.arrival_us - ev.time_us for ev in requeues]
    assert gaps == [10_000.0, 20_000.0, 40_000.0]
    assert frt.recoveries[-1].kind == "cold"
    assert not frt._retryq


# --------------------------------------------------------------------------
# link faults and task crashes
# --------------------------------------------------------------------------


def test_link_degrade_slows_transfers_and_restore_heals():
    topo = homogeneous(2, RTX5080, capacity_bytes=4 << 30, nvlink_gbps=NV)
    nbytes = 1 << 30
    healthy = topo.plan_transfer("gpu0", "gpu1", nbytes, 0.0)
    topo.reset_transfers()
    topo.degrade("gpu0", "gpu1", 0.25)
    degraded = topo.plan_transfer("gpu0", "gpu1", nbytes, 0.0)
    assert degraded.arrival_us == pytest.approx(4 * healthy.arrival_us)
    topo.restore("gpu0", "gpu1")
    topo.reset_transfers()
    healed = topo.plan_transfer("gpu0", "gpu1", nbytes, 0.0)
    assert healed.arrival_us == healthy.arrival_us


def test_nvlink_edge_down_falls_back_to_host_path():
    topo = homogeneous(2, RTX5080, capacity_bytes=4 << 30, nvlink_gbps=NV)
    assert topo.nvlink_peer("gpu0", "gpu1") is not None
    topo.degrade("gpu0", "gpu1", 0.0)  # edge down, not just slow
    assert topo.nvlink_peer("gpu0", "gpu1") is None
    path = topo.path("gpu0", "gpu1")
    assert [(l.a, l.b) for l in path] == [("gpu0", HOST), ("gpu1", HOST)]
    # host PCIe links refuse factor 0 — a GPU with no host path is a
    # failed GPU, not a slow link
    with pytest.raises(ValueError):
        topo.degrade("gpu0", HOST, 0.0)
    topo.restore("gpu0", "gpu1")
    assert topo.nvlink_peer("gpu0", "gpu1") is not None


def test_task_crash_kills_and_recovers_one_task():
    inj = FaultInjector([
        FaultEvent(300_000.0, "task_crash", task_id=0),
    ])
    # one long decode: multi-quantum, guaranteed to be switched in (and so
    # crashable) at the fault instant
    tr = Trace([Request(0, ARCH, 1_000.0, prompt_tokens=64, output_tokens=400)])
    rep = simulate_cluster(
        tr, homogeneous(2, RTX5080, capacity_bytes=4 << 30),
        backend="msched", placement="leastloaded",
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE, faults=inj, audit=True, sim_us=8_000_000.0,
    )
    assert rep.faults_applied == 1
    assert len(rep.recoveries) == 1 and rep.recoveries[0].task_id == 0
    crashed = [
        r for g in rep.per_gpu for r in g.result.requests
        if "crashed_us" in r.meta
    ]
    assert len(crashed) == 1 and crashed[0].task_id == 0
    assert rep.stats.n_finished == rep.stats.n_requests


# --------------------------------------------------------------------------
# graceful degradation: shed best-effort before RT
# --------------------------------------------------------------------------


def test_shedding_takes_best_effort_before_rt():
    """Half the fleet dies under queued load: the survivors shed queued
    best-effort candidates first; RT requests are never shed at the default
    (rt-protecting) thresholds."""
    topo = homogeneous(2, RTX5080, capacity_bytes=1 << 30)
    cores = []
    for name in ("gpu0", "gpu1"):
        core = _serving_core(name, req_id={"gpu0": 0, "gpu1": 1}[name],
                             output_tokens=200)
        cores.append(core)
    g0, g1 = cores
    # queue a pile of mixed-class candidates behind gpu0's admission
    for i, klass in enumerate(["be", "rt", "be", "rt", "be", "be"]):
        req = Request(100 + i, ARCH, 10_000.0 + i, prompt_tokens=512,
                      output_tokens=64, slo_class=klass)
        g0.inject(TaskArrival(
            req.arrival_us, ServedRequestTask(100 + i, req, page_size=PAGE),
            meta={"slo_class": klass},
        ))
    g0.admission = type("QueueAll", (AdmissionController,), {
        "decide": lambda self, prog, arrival_us, state: "queue"
        if state.active else "admit"
    })()
    # past the first 350k-us timeslice: the second step boundary processes
    # the queued arrivals through the admission controller
    g0.run(400_000.0, final=False)
    assert len(g0.waiting) >= 5
    frt = _runtime(
        [FaultEvent(g0.t, "gpu_fail", gpu="gpu1")], topo, [g0, g1],
        shed_threshold=0.5,
    )
    frt.apply_due(g0.t)
    assert frt.shed_events, "pressure above threshold must shed"
    assert all(klass == "be" for _t, _tid, klass, _c in frt.shed_events)
    # every shed landed on a record, and RT candidates survived the cut
    shed_ids = {tid for _t, tid, _k, _c in frt.shed_events}
    for rec in g0.records:
        if rec.task_id in shed_ids:
            assert rec.rejected and "shed_us" in rec.meta
    waiting_ids = {ev.program.task_id for ev, _r, _p in g0.waiting}
    assert {101, 103} <= waiting_ids, "rt requests must survive"


def test_shed_rt_threshold_allows_rt_shedding_when_set():
    topo = homogeneous(1, RTX5080, capacity_bytes=1 << 30)
    g0 = _serving_core("gpu0", req_id=0, output_tokens=200)
    for i, klass in enumerate(["rt", "rt", "rt"]):
        req = Request(100 + i, ARCH, 10_000.0 + i, prompt_tokens=512,
                      output_tokens=64, slo_class=klass)
        g0.inject(TaskArrival(
            req.arrival_us, ServedRequestTask(100 + i, req, page_size=PAGE),
            meta={"slo_class": klass},
        ))
    g0.admission = type("QueueAll", (AdmissionController,), {
        "decide": lambda self, prog, arrival_us, state: "queue"
        if state.active else "admit"
    })()
    g0.run(400_000.0, final=False)
    assert len(g0.waiting) >= 2
    frt = _runtime([], topo, [g0], shed_threshold=0.1,
                   shed_rt_threshold=0.1)
    frt._shed_pressure(g0.t)
    assert any(k == "rt" for _t, _tid, k, _c in frt.shed_events)


def _queued_core(classes, cap=1 << 30):
    """One serving core with a pile of queued mixed-class candidates —
    admission queues everything behind the running request, and one
    scheduler step past the first timeslice has processed the queue."""
    g0 = _serving_core("gpu0", req_id=0, output_tokens=200, cap=cap)
    for i, klass in enumerate(classes):
        req = Request(100 + i, ARCH, 10_000.0 + i, prompt_tokens=512,
                      output_tokens=64, slo_class=klass)
        g0.inject(TaskArrival(
            req.arrival_us, ServedRequestTask(100 + i, req, page_size=PAGE),
            meta={"slo_class": klass},
        ))
    g0.admission = type("QueueAll", (AdmissionController,), {
        "decide": lambda self, prog, arrival_us, state: "queue"
        if state.active else "admit"
    })()
    g0.run(400_000.0, final=False)
    assert len(g0.waiting) >= len(classes)
    return g0


def test_shed_threshold_boundary_is_strict():
    """The shed loop runs while pressure is *strictly above* the
    threshold: a fleet at exactly ``shed_threshold`` sheds nothing, and
    one ulp below the measured pressure sheds."""
    topo = homogeneous(1, RTX5080, capacity_bytes=1 << 30)
    g0 = _queued_core(["be", "be", "be", "be"])
    pressure = _runtime([], topo, [g0], shed_threshold=None).fleet_pressure()
    assert pressure > 0.0
    at = _runtime([], topo, [g0], shed_threshold=pressure)
    at._shed_pressure(g0.t)
    assert not at.shed_events, "pressure == threshold must not shed"
    n_waiting = len(g0.waiting)
    below = _runtime(
        [], topo, [g0], shed_threshold=math.nextafter(pressure, 0.0)
    )
    below._shed_pressure(g0.t)
    assert below.shed_events, "pressure one ulp above threshold must shed"
    assert len(g0.waiting) < n_waiting


def test_shed_rt_threshold_boundary_is_strict():
    """Same strictness for the RT rung: an all-RT queue at exactly
    ``shed_rt_threshold`` survives; one ulp below, RT work is shed."""
    topo = homogeneous(1, RTX5080, capacity_bytes=1 << 30)
    g0 = _queued_core(["rt", "rt", "rt"])
    pressure = _runtime([], topo, [g0], shed_threshold=None).fleet_pressure()
    at = _runtime([], topo, [g0], shed_threshold=pressure,
                  shed_rt_threshold=pressure)
    at._shed_pressure(g0.t)
    assert not at.shed_events
    eps = math.nextafter(pressure, 0.0)
    below = _runtime([], topo, [g0], shed_threshold=eps,
                     shed_rt_threshold=eps)
    below._shed_pressure(g0.t)
    assert any(k == "rt" for _t, _tid, k, _c in below.shed_events)


def test_rt_shed_implies_no_be_survivor_in_same_pass():
    """The BE rung drains completely before the RT rung fires: any pass
    that sheds an RT candidate has already shed every queued BE one."""
    topo = homogeneous(1, RTX5080, capacity_bytes=1 << 30)
    g0 = _queued_core(["be", "rt", "be", "rt", "be"])
    frt = _runtime([], topo, [g0], shed_threshold=0.1,
                   shed_rt_threshold=0.1)
    frt._shed_pressure(g0.t)
    classes = [k for _t, _tid, k, _c in frt.shed_events]
    assert "rt" in classes
    first_rt = classes.index("rt")
    assert "be" not in classes[first_rt:], \
        "every BE shed must precede the first RT shed"
    waiting_classes = {
        (ev.meta or {}).get("slo_class") for ev, _r, _p in g0.waiting
    }
    assert "be" not in waiting_classes, \
        "an RT shed with a BE survivor violates the degradation order"


def test_rt_threshold_below_be_threshold_rejected():
    topo = homogeneous(1, RTX5080, capacity_bytes=1 << 30)
    g0 = _serving_core("gpu0")
    with pytest.raises(ValueError, match="shed_rt_threshold"):
        _runtime([], topo, [g0], shed_threshold=0.5, shed_rt_threshold=0.4)


# --------------------------------------------------------------------------
# satellite: rebalancer retry exhaustion
# --------------------------------------------------------------------------


class RejectAll(AdmissionController):
    def decide(self, prog, arrival_us, state):
        return "reject"


def test_retry_exhaustion_accounts_and_releases_reservations():
    """A continuation every GPU rejects exhausts its retry budget: the
    rejection stands, the exhaustion is counted and stamped on the record,
    and the parked staging reservation + linger copy are released."""
    topo = homogeneous(2, RTX5080, capacity_bytes=4 << 30)
    src = _serving_core("gpu0", req_id=0, output_tokens=300)
    dst = _serving_core("gpu1", req_id=1, output_tokens=2)
    rb = Rebalancer(topo, max_retries=2)
    rb.attach([src, dst])
    src.run(200_000.0, final=False)
    mv = rb._move_one(src, dst, 200_000.0)
    assert mv is not None and mv.kind == "checkpoint"
    # the checkpointed working set is parked in host staging until consumed
    assert rb._staged_plans and topo.host_staged_bytes(200_001.0) > 0
    src.admission = RejectAll()
    dst.admission = RejectAll()
    for _ in range(6):
        dst.run(dst.t + 1_000_000.0, final=False)
        src.run(src.t + 1_000_000.0, final=False)
    assert rb.exhausted == 1
    exhausted = [e for e in rb.events if e.kind == "exhausted"]
    assert len(exhausted) == 1 and exhausted[0].task_id == 0
    # the stranded reservation was cancelled, not leaked
    assert not rb._staged_plans
    assert topo.host_staged_bytes(200_001.0) == 0
    frags = [r for r in src.records + dst.records if r.task_id == 0]
    assert any(r.rejected and r.meta.get("retry_exhausted") for r in frags)


def test_retry_backoff_spaces_bounces():
    """``retry_backoff_us`` makes each bounce land later (capped), instead
    of the default instant re-injection."""
    topo = homogeneous(2, RTX5080, capacity_bytes=4 << 30)
    src = _serving_core("gpu0", req_id=0, output_tokens=300)
    dst = _serving_core("gpu1", req_id=1, output_tokens=2)
    rb = Rebalancer(topo, max_retries=3, retry_backoff_us=50_000.0,
                    retry_backoff_cap_us=80_000.0)
    rb.attach([src, dst])
    src.run(200_000.0, final=False)
    assert rb._move_one(src, dst, 200_000.0) is not None
    src.admission = RejectAll()
    dst.admission = RejectAll()
    for _ in range(8):
        dst.run(dst.t + 1_000_000.0, final=False)
        src.run(src.t + 1_000_000.0, final=False)
    retries = [e for e in rb.events if e.kind == "retry"]
    assert len(retries) == 3
    gaps = [e.arrival_us - e.time_us for e in retries]
    assert gaps == [50_000.0, 80_000.0, 80_000.0]  # 50, min(100, cap), cap


# --------------------------------------------------------------------------
# satellite: linger lifecycle vs in-flight retries
# --------------------------------------------------------------------------


def test_exhausted_retry_releases_linger_copy():
    """When a lazily-migrated continuation's retries exhaust, the lingering
    source copy is reclaimed — no orphaned LingerEntry, no leaked pages,
    and no double-free when the source later reaps."""
    topo = homogeneous(2, RTX5080, capacity_bytes=4 << 30, nvlink_gbps=NV)
    g0 = _serving_core("gpu0", req_id=0, output_tokens=300)
    g1 = _serving_core("gpu1", req_id=1, output_tokens=2)
    fabric = PeerPrefetchFabric(topo, [g0, g1])
    fabric.wire()
    rb = Rebalancer(topo, prefetch=fabric, max_retries=0)
    rb.attach([g0, g1])
    g0.run(200_000.0, final=False)
    mv = rb._move_one(g0, g1, 200_000.0)
    assert mv is not None and mv.kind == "p2p"
    assert fabric.directory.get(0) is not None and g0.pool.used > 0
    # with a zero retry budget the very first rejection exhausts
    g1.admission = RejectAll()
    g1.run(mv.arrival_us + 1_000_000.0, final=False)
    assert rb.exhausted == 1
    assert fabric.directory.get(0) is None
    assert 0 not in g0.lingering
    assert g0.pool.used == 0, "linger pages must be reclaimed, not leaked"
    # reaping again is a no-op, not a double-free
    assert fabric.reap(final=True) == 0
    InvariantAuditor([g0, g1], topology=topo, fabric=fabric).check(
        g1.t, "post-exhaust"
    )
    g0.run(30_000_000.0, final=True)
    g1.run(30_000_000.0, final=True)
    frags = [r for r in g0.records + g1.records if r.task_id == 0]
    assert any(r.rejected for r in frags)
    assert g0.pool.used == 0 and g1.pool.used == 0


def test_shed_waiting_task_releases_linger_copy():
    """A queued continuation shed by graceful degradation releases its
    lingering working set on the peer — shedding while the retry was in
    flight must not strand the copy."""
    topo = homogeneous(2, RTX5080, capacity_bytes=4 << 30, nvlink_gbps=NV)
    g0 = _serving_core("gpu0", req_id=0, output_tokens=300)
    g1 = _serving_core("gpu1", req_id=1, output_tokens=2)
    fabric = PeerPrefetchFabric(topo, [g0, g1])
    fabric.wire()
    rb = Rebalancer(topo, prefetch=fabric)
    rb.attach([g0, g1])
    g0.run(200_000.0, final=False)
    mv = rb._move_one(g0, g1, 200_000.0)
    assert mv is not None and mv.kind == "p2p"
    # the continuation queues behind gpu1's admission (unconditionally:
    # gpu1 is idle when it lands, so an active-gated stub would admit it)
    g1.admission = type("QueueAll", (AdmissionController,), {
        "decide": lambda self, prog, arrival_us, state: "queue"
    })()
    g1.run(mv.arrival_us + 1_000.0, final=False)
    assert g1.waiting
    frt = _runtime([], topo, [g0, g1], fabric=fabric, shed_threshold=0.0)
    frt._shed_pressure(g1.t)
    assert any(tid == 0 for _t, tid, _k, _c in frt.shed_events)
    assert fabric.directory.get(0) is None
    assert 0 not in g0.lingering and g0.pool.used == 0
    InvariantAuditor([g0, g1], topology=topo, fabric=fabric).check(
        g1.t, "post-shed"
    )


# --------------------------------------------------------------------------
# seeded chaos: the auditor rides along
# --------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [1, 2, 3, 4, 5, 6])
def test_chaos_schedule_keeps_invariants_and_accounting(seed):
    """Random fail/flap/crash schedules with the inline auditor: zero
    violations, and every request is accounted — finished, rejected, or
    explicitly lost — with balanced HBM at the end."""
    tr = _trace(rate=5.0, duration=0.8, seed=seed, output_mean=12)
    topo = homogeneous(2, RTX5080, capacity_bytes=3 << 30, nvlink_gbps=NV)
    inj = FaultInjector.random(
        topo, 1_500_000.0, seed=seed,
        gpu_mtbf_us=700_000.0, gpu_mttr_us=300_000.0,
        link_mtbf_us=900_000.0, crash_mtbf_us=1_200_000.0,
    )
    rep = simulate_cluster(
        tr, topo, backend="msched", placement="leastloaded",
        admission_factory=lambda i: MSchedAdmission(headroom=0.9),
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE, faults=inj, audit=True,
        checkpoint_period_us=200_000.0, drain_factor=25.0,
    )
    # audit=True raised on any violation; accounting must balance
    assert {r.task_id for r in rep.merged.requests} == {
        r.req_id for r in tr
    }
    unresolved = [
        r for r in rep.merged.requests
        if r.finished_us is None and not r.rejected
    ]
    assert not unresolved, f"unaccounted requests: {unresolved}"
    assert rep.merged.hbm_used_pages == 0
