"""Topology model: link graph, bandwidth contention, host DRAM budget."""
import pytest

from repro.cluster.topology import (
    HOST,
    ClusterTopology,
    GPUNode,
    homogeneous,
    mixed,
)
from repro.core.hardware import A100_40G, A100_80G, RTX5080


def test_homogeneous_builds_host_links():
    topo = homogeneous(3, RTX5080)
    assert len(topo) == 3
    for g in topo.gpus:
        link = topo.link(g.name, HOST)
        assert link is not None and link.kind == "pcie"
        assert link.gbps == min(RTX5080.d2h_gbps, RTX5080.h2d_gbps)
    assert topo.link("gpu0", "gpu1") is None
    # host-staged two-hop path
    path = topo.path("gpu0", "gpu2")
    assert [l.kind for l in path] == ["pcie", "pcie"]


def test_nvlink_mesh_gives_direct_path():
    topo = homogeneous(2, RTX5080, nvlink_gbps=300.0)
    path = topo.path("gpu0", "gpu1")
    assert len(path) == 1 and path[0].kind == "nvlink"
    assert path[0].gbps == 300.0


def test_capacity_override_and_mixed():
    topo = mixed([(A100_40G, 10 << 30), (A100_80G, None)])
    assert topo.gpus[0].hbm_bytes == 10 << 30
    assert topo.gpus[1].hbm_bytes == 80 << 30
    # per-GPU host link tracks each device's own PCIe bandwidth
    assert topo.link("gpu0", HOST).gbps == A100_40G.d2h_gbps
    assert topo.link("gpu1", HOST).gbps == A100_80G.d2h_gbps


def test_duplicate_names_rejected():
    with pytest.raises(ValueError):
        ClusterTopology([GPUNode("g", RTX5080), GPUNode("g", RTX5080)])
    with pytest.raises(ValueError):
        ClusterTopology([GPUNode("g", RTX5080)], nvlinks=[("g", "nope", 10.0)])


def test_host_staged_transfer_timing():
    topo = homogeneous(2, RTX5080)
    nbytes = 1 << 30
    plan = topo.plan_transfer("gpu0", "gpu1", nbytes, now=1000.0)
    assert plan is not None and plan.staged
    leg_us = nbytes / (RTX5080.d2h_gbps * 1e3)
    assert plan.arrival_us == pytest.approx(1000.0 + 2 * leg_us)
    assert len(plan.legs) == 2
    # staged bytes occupy host DRAM until the transfer lands
    assert topo.host_staged_bytes(plan.start_us) == nbytes
    assert topo.host_staged_bytes(plan.arrival_us + 1.0) == 0


def test_p2p_transfer_skips_host_budget():
    topo = homogeneous(2, RTX5080, host_dram_bytes=1 << 20, nvlink_gbps=300.0)
    nbytes = 1 << 30  # far beyond the 1 MiB host budget
    plan = topo.plan_transfer("gpu0", "gpu1", nbytes, now=0.0)
    assert plan is not None and not plan.staged
    assert plan.arrival_us == pytest.approx(nbytes / (300.0 * 1e3))
    assert topo.deferred == 0


def test_link_contention_halves_bandwidth():
    topo = homogeneous(3, RTX5080)
    nbytes = 1 << 30
    leg_us = nbytes / (RTX5080.d2h_gbps * 1e3)
    a = topo.plan_transfer("gpu0", "gpu1", nbytes, now=0.0)
    # second transfer from the same source while the first still occupies the
    # gpu0<->host link: that leg runs at half bandwidth...
    b = topo.plan_transfer("gpu0", "gpu2", nbytes, now=0.0)
    assert b.legs[0][1] == pytest.approx(2 * leg_us)
    # ...and the second leg (gpu2's own link, uncontended at its start) at
    # full bandwidth
    assert b.arrival_us == pytest.approx(3 * leg_us)
    assert a.arrival_us == pytest.approx(2 * leg_us)
    # once everything drained, a new transfer sees full bandwidth again
    c = topo.plan_transfer("gpu0", "gpu1", nbytes, now=b.arrival_us + 1.0)
    assert c.arrival_us - c.start_us == pytest.approx(2 * leg_us)


def test_host_dram_budget_defers():
    topo = homogeneous(2, RTX5080, host_dram_bytes=1 << 30)
    ok = topo.plan_transfer("gpu0", "gpu1", 800 << 20, now=0.0)
    assert ok is not None
    denied = topo.plan_transfer("gpu1", "gpu0", 800 << 20, now=0.0)
    assert denied is None
    assert topo.deferred == 1
    # after the first staging drains, the same transfer fits
    late = topo.plan_transfer("gpu1", "gpu0", 800 << 20, now=ok.arrival_us + 1.0)
    assert late is not None


def test_transfer_to_self_rejected():
    topo = homogeneous(2, RTX5080)
    with pytest.raises(ValueError):
        topo.plan_transfer("gpu0", "gpu0", 1 << 20, now=0.0)
