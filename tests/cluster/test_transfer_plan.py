"""Transfer planner: randomized conservation suite plus the pinned
regressions around fluid-share staleness, cancel accounting, routing,
urgency deferral, and the peer-pressure scavenger-progress guarantee.

The conservation properties run the planner over *random* topologies and
request storms and check the committed piecewise-constant schedule the way
an auditor would: integrate it. Landing times are cross-checked against an
independent event-loop replay of the same fluid model (written here, not
shared with the planner), so a planner bookkeeping bug cannot cancel out.
"""
import json
import math
import random

import pytest

try:  # optional dev dependency (requirements-dev.txt)
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:
    st = None

from repro.cluster import homogeneous, simulate_cluster
from repro.cluster.topology import HOST, ClusterTopology, GPUNode, LingerEntry
from repro.cluster.transfer_plan import (
    URGENCY_RESTORE,
    URGENCY_RT,
    TransferPlanner,
    TransferRequest,
)
from repro.core.hardware import A100_40G, NVLINK_A100_GBPS, RTX5080
from repro.core.scheduler import RoundRobinPolicy
from repro.serving import AlwaysAdmit, MSchedAdmission, poisson_trace

PAGE = 1 << 20
GB = 1 << 30
MB = 1 << 20


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------


def _random_topology(rnd):
    """2-5 identical GPUs, each peer edge present with p=0.45."""
    n = rnd.randint(2, 5)
    names = [f"gpu{i}" for i in range(n)]
    nvlinks = [
        (names[i], names[j], NVLINK_A100_GBPS)
        for i in range(n)
        for j in range(i + 1, n)
        if rnd.random() < 0.45
    ]
    return ClusterTopology(
        [GPUNode(nm, A100_40G) for nm in names],
        host_dram_bytes=512 << 30,
        nvlinks=nvlinks,
    )


def _random_requests(rnd, topo, n):
    gpus = [g.name for g in topo.gpus]
    reqs = []
    for _ in range(n):
        shape = rnd.random()
        if shape < 0.15:  # restore: host -> gpu
            src, dst, kind = HOST, rnd.choice(gpus), "restore"
        elif shape < 0.3:  # snapshot: gpu -> host
            src, dst, kind = rnd.choice(gpus), HOST, "snapshot"
        else:  # inter-GPU move
            src, dst = rnd.sample(gpus, 2)
            kind = rnd.choice(["checkpoint", "p2p", "peer_fetch", "bulk"])
        urgency = rnd.choice([None, URGENCY_RT, URGENCY_RESTORE])
        reqs.append(
            TransferRequest(src, dst, rnd.randint(1 * MB, 2 * GB), kind,
                            urgency, task_id=rnd.randrange(1000))
        )
    return reqs


def _run_random_storm(seed):
    """Drive a planner through 1-3 submission windows on a random topology;
    return (planner, topology) with the schedule fully committed."""
    rnd = random.Random(seed)
    topo = _random_topology(rnd)
    planner = TransferPlanner(topo)
    topo.planner = planner
    t = 0.0
    for _ in range(rnd.randint(1, 3)):
        planner.submit(_random_requests(rnd, topo, rnd.randint(2, 8)), t)
        t += rnd.uniform(1_000.0, 300_000.0)
    planner._advance(t + 1e9)  # commit the whole schedule into history
    return planner, topo


def _reference_landings(flights):
    """Independent event-loop replay of the equal-share fluid model over the
    admitted flights (staggered admissions, per-flight frozen leg caps).
    Returns {fid: [absolute leg end, ...]} — the ground truth the planner's
    committed plans must match."""
    pending = sorted(flights, key=lambda f: (f.start_us, f.fid))
    i = 0
    active = []  # dicts: fid, keys, caps, leg, rem, ends, nbytes
    out = {}
    t = 0.0
    while i < len(pending) or active:
        if not active:
            t = max(t, pending[i].start_us)
        while i < len(pending) and pending[i].start_us <= t + 1e-9:
            f = pending[i]
            i += 1
            active.append({
                "fid": f.fid, "keys": [l.key() for l in f.links],
                "caps": f.caps, "leg": 0, "rem": float(f.req.nbytes),
                "ends": [], "nbytes": f.req.nbytes,
            })
        occ = {}
        for a in active:
            k = a["keys"][a["leg"]]
            occ[k] = occ.get(k, 0) + 1
        dt = math.inf
        rates = []
        for a in active:
            r = a["caps"][a["leg"]] / occ[a["keys"][a["leg"]]]
            rates.append(r)
            if r > 0.0:
                dt = min(dt, a["rem"] / r)
        t_adm = pending[i].start_us if i < len(pending) else math.inf
        end = min(t + dt, t_adm)
        for a, r in zip(active, rates):
            a["rem"] -= r * (end - t)
        t = end
        done = []
        for a, r in zip(active, rates):
            eps = 1e-6 + 1e-9 * a["nbytes"]
            stuck = r > 0.0 and a["rem"] / r <= 4.0 * math.ulp(max(t, 1.0))
            if r > 0.0 and (a["rem"] <= eps or stuck):
                a["ends"].append(t)
                a["leg"] += 1
                if a["leg"] >= len(a["keys"]):
                    out[a["fid"]] = a["ends"]
                    done.append(a)
                else:
                    a["rem"] = float(a["nbytes"])
        for a in done:
            active.remove(a)
    return out


# --------------------------------------------------------------------------
# the conservation properties
# --------------------------------------------------------------------------


def _check_link_conservation(seed):
    """Property 1: bytes in == bytes out. For every admitted flight and
    every leg, the integral of its committed per-segment rates over the
    link equals exactly the flight's payload."""
    planner, _ = _run_random_storm(seed)
    for f in planner.log:
        for link in f.links:
            moved = sum(
                (t1 - t0) * rate
                for (t0, t1, flows) in planner.history.get(link.key(), [])
                for (fid, rate) in flows
                if fid == f.fid
            )
            assert abs(moved - f.req.nbytes) <= max(1.0, 1e-6 * f.req.nbytes), (
                f"flight {f.fid} moved {moved} of {f.req.nbytes} bytes on "
                f"{link.a}<->{link.b}"
            )


def _check_capacity_respected(seed):
    """Property 2: no link exceeds its capacity in any committed segment."""
    planner, topo = _run_random_storm(seed)
    for key, segments in planner.history.items():
        link = topo._links[key]
        cap = link.gbps * 1e3  # bytes/us; suite never degrades
        for (t0, t1, flows) in segments:
            total = sum(rate for _, rate in flows)
            assert total <= cap * (1.0 + 1e-9), (
                f"link {sorted(key)} oversubscribed: {total} > {cap} "
                f"in segment [{t0}, {t1})"
            )


def _check_landings_match_reference(seed):
    """Property 3: every committed plan's leg ends (and hence its arrival)
    equal the independent event-loop replay of the same admissions."""
    planner, _ = _run_random_storm(seed)
    truth = _reference_landings(planner.log)
    for f in planner.log:
        assert f.plan is not None
        want = truth[f.fid]
        got = [end for _, end in f.plan.legs]
        assert len(got) == len(want)
        for g, w in zip(got, want):
            assert abs(g - w) <= 1e-3 + 1e-9 * w, (
                f"flight {f.fid}: planned legs {got} != replayed {want}"
            )
        assert abs(f.plan.arrival_us - want[-1]) <= 1e-3 + 1e-9 * want[-1]


def _check_ledgers_settle(seed):
    """Property 4: once the schedule fully drains, the topology's shared
    ledgers read empty — no phantom sharers, bytes, or stagings survive a
    planned storm (greedy probes and planner bookkeeping agree at the
    fixpoint)."""
    planner, topo = _run_random_storm(seed)
    assert planner._flights == []
    assert planner.landed == len(planner.log)
    horizon = 1e15
    for link in topo.links():
        assert topo.active_on(link.a, link.b, horizon) == 0
        assert topo.inflight_bytes(link.a, link.b, horizon) == 0
    assert topo.host_staged_bytes(horizon) == 0
    # every committed plan is internally consistent: monotone leg ends,
    # arrival == last leg
    for f in planner.log:
        ends = [e for _, e in f.plan.legs]
        assert all(b >= a for a, b in zip(ends, ends[1:]))
        assert f.plan.arrival_us == ends[-1]
        assert f.plan.arrival_us >= f.plan.start_us


_PROPERTIES = [
    _check_link_conservation,
    _check_capacity_respected,
    _check_landings_match_reference,
    _check_ledgers_settle,
]

if st is not None:

    @pytest.mark.parametrize("prop", _PROPERTIES)
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 10**6))
    def test_property_conservation(prop, seed):
        prop(seed)

else:  # deterministic fallback when hypothesis is unavailable

    @pytest.mark.parametrize("seed", range(25))
    @pytest.mark.parametrize("prop", _PROPERTIES)
    def test_property_conservation(prop, seed):
        prop(7919 * seed + 13)


# --------------------------------------------------------------------------
# greedy mode is pinned bit-for-bit, for every backend
# --------------------------------------------------------------------------


def _trace():
    return poisson_trace(
        4.0, 0.7, seed=17, tenants=("qwen3-1.7b",), prompt_mean=48,
        output_mean=6, max_output=12,
    )


@pytest.mark.parametrize("backend", ["um", "msched", "ideal", "suv"])
def test_transfer_plan_greedy_is_bit_for_bit(backend):
    """``transfer_plan="greedy"`` (explicit) is byte-identical JSON to the
    default for every memory backend — the flag's default path constructs
    nothing."""
    quantum = 2_000.0 if backend == "um" else 350_000.0
    mk_admission = (
        (lambda: MSchedAdmission(headroom=0.9))
        if backend in ("msched", "ideal")
        else (lambda: AlwaysAdmit())
    )

    def run(**kw):
        return simulate_cluster(
            _trace(), homogeneous(2, RTX5080, capacity_bytes=2 << 30),
            backend=backend, placement="roundrobin",
            admission_factory=lambda i: mk_admission(),
            policy_factory=lambda i: RoundRobinPolicy(quantum),
            page_size=PAGE, rebalance_period_us=80_000.0, **kw,
        )

    a = json.dumps(run().to_json(), sort_keys=True)
    b = json.dumps(run(transfer_plan="greedy").to_json(), sort_keys=True)
    assert a == b
    doc = json.loads(a)
    assert doc["planned_transfers"] == 0
    assert doc["planner_replans"] == 0


def test_transfer_plan_flag_validated():
    with pytest.raises(ValueError, match="transfer_plan"):
        simulate_cluster(
            _trace(), homogeneous(2, RTX5080, capacity_bytes=2 << 30),
            transfer_plan="eager",
        )


def test_transfer_plan_auto_single_gpu_matches_greedy():
    """1-GPU fleets have nothing to schedule: "auto" must not build the
    planner, and the run is bit-for-bit greedy."""

    def run(**kw):
        return simulate_cluster(
            _trace(), homogeneous(1, RTX5080, capacity_bytes=2 << 30),
            backend="msched", placement="roundrobin",
            admission_factory=lambda i: MSchedAdmission(headroom=0.9),
            policy_factory=lambda i: RoundRobinPolicy(350_000.0),
            page_size=PAGE, **kw,
        )

    a = json.dumps(run().to_json(), sort_keys=True)
    b = json.dumps(run(transfer_plan="auto").to_json(), sort_keys=True)
    assert a == b


# --------------------------------------------------------------------------
# fluid-share staleness: the regression the planner exists to fix
# --------------------------------------------------------------------------


def test_two_sharers_one_drains_landing_is_exact():
    """Two flights share one host link; the small one drains first. The
    greedy fluid-at-start estimate prices the big flight at half rate for
    its whole lifetime; the planner's estimate must equal the true DES
    landing (half rate until the drain, full rate after)."""
    topo = homogeneous(2, A100_40G)  # no NVLink: both route over one host leg
    planner = TransferPlanner(topo)
    topo.planner = planner
    cap = topo.link("gpu0", HOST).gbps * 1e3  # bytes/us
    big, small = 2 * GB, GB // 2
    # same src so both contend on gpu0<->host; HOST dst keeps it single-leg
    plans = planner.submit(
        [TransferRequest("gpu0", HOST, big, "snapshot"),
         TransferRequest("gpu0", HOST, small, "snapshot")],
        0.0,
    )
    # truth: both at cap/2 until the small lands, then the big solo
    t_small = small / (cap / 2.0)
    t_big = t_small + (big - small) / cap
    assert plans[1].arrival_us == pytest.approx(t_small, rel=1e-9)
    assert plans[0].arrival_us == pytest.approx(t_big, rel=1e-9)
    # and strictly better than the stale fluid-at-start estimate
    greedy_estimate = big / (cap / 2.0)
    assert plans[0].arrival_us < greedy_estimate


def test_later_admission_rebooks_earlier_flight():
    """Admitting a second flight onto a shared link slows the first one:
    its committed plan must be rewritten in place and the replan counted."""
    topo = homogeneous(2, A100_40G)
    planner = TransferPlanner(topo)
    topo.planner = planner
    retimed = []
    topo.replan_hook = lambda plan, old: retimed.append((plan, old))
    p1 = planner.submit_one(
        TransferRequest("gpu0", HOST, GB, "snapshot", task_id=1), 0.0
    )
    solo_arrival = p1.arrival_us
    planner.submit_one(
        TransferRequest("gpu0", HOST, GB, "snapshot", task_id=2,
                        urgency=URGENCY_RT), 0.0
    )
    assert p1.arrival_us > solo_arrival  # rewritten in place
    assert topo.replans == 1
    assert retimed and retimed[0][0] is p1 and retimed[0][1] == solo_arrival
    # the probe ledgers moved with the rebook
    assert topo.active_on("gpu0", HOST, p1.arrival_us - 1.0) == 2


def test_cancel_rebooks_survivor_to_recovered_share():
    """Canceling one of two sharers hands the survivor the full link: its
    plan must land earlier than the shared estimate."""
    topo = homogeneous(2, A100_40G)
    planner = TransferPlanner(topo)
    topo.planner = planner
    cap = topo.link("gpu0", HOST).gbps * 1e3
    plans = planner.submit(
        [TransferRequest("gpu0", "gpu1", GB, "checkpoint", URGENCY_RT, 1),
         TransferRequest("gpu0", "gpu1", GB, "checkpoint", URGENCY_RT, 2)],
        0.0,
    )
    shared = plans[0].arrival_us
    t_cancel = 1_000.0
    topo.cancel_staging(plans[1], t_cancel)
    assert plans[1].canceled_us == t_cancel
    assert plans[0].arrival_us < shared
    # exact: half rate to the cancel, full rate after, then the solo dst leg
    moved = (cap / 2.0) * t_cancel
    leg1 = t_cancel + (GB - moved) / cap
    t_land = leg1 + GB / cap
    assert plans[0].arrival_us == pytest.approx(t_land, rel=1e-9)


# --------------------------------------------------------------------------
# cancel accounting at completion boundaries (the inflight_bytes fix)
# --------------------------------------------------------------------------


def test_cancel_and_retry_never_double_count_inflight():
    """Greedy mode: a staged transfer canceled at ``t`` and replanned at the
    same ``t`` must count once in ``inflight_bytes`` — before the
    ``canceled_us`` marker the dead plan's legs kept counting forever."""
    topo = homogeneous(2, A100_40G)  # host-staged (no NVLink)
    nbytes = GB
    p1 = topo.plan_transfer("gpu0", "gpu1", nbytes, 0.0)
    t = 5_000.0
    assert topo.cancel_staging(p1, t) == nbytes
    p2 = topo.plan_transfer("gpu0", "gpu1", nbytes, t)
    assert p2 is not None
    # the probe sees only the retry from the cancel instant on
    assert topo.inflight_bytes("gpu0", HOST, t) == nbytes
    assert topo.inflight_bytes("gpu0", HOST, t - 1.0) == nbytes  # old, pre-cancel
    # and the canceled plan's staging reservation is gone
    assert topo.host_staged_bytes(t) == nbytes


def test_completion_boundary_never_double_counts():
    """A transfer completing at ``t`` and another starting at ``t`` count
    once: a leg covers ``[start, end)``."""
    topo = homogeneous(2, A100_40G)
    p1 = topo.plan_transfer("gpu0", HOST, GB, 0.0)
    t = p1.arrival_us
    assert topo.inflight_bytes("gpu0", HOST, t) == 0  # p1 just landed
    p2 = topo.plan_transfer("gpu0", HOST, GB, t)
    assert topo.inflight_bytes("gpu0", HOST, t) == GB  # exactly the new one
    assert topo.inflight_bytes("gpu0", HOST, t - 1.0) == GB  # exactly the old
    assert p2.arrival_us > t


def test_cancel_without_timestamp_keeps_legacy_accounting():
    """``cancel_staging`` without ``at_us`` (legacy callers) releases the
    staging but leaves the in-flight probe conservative — unchanged."""
    topo = homogeneous(2, A100_40G)
    p1 = topo.plan_transfer("gpu0", "gpu1", GB, 0.0)
    topo.cancel_staging(p1)
    assert p1.canceled_us is None
    assert topo.inflight_bytes("gpu0", HOST, 1.0) == GB


# --------------------------------------------------------------------------
# routing and urgency
# --------------------------------------------------------------------------


def test_saturated_host_link_takes_idle_nvlink_detour():
    """gpu0->gpu1 has no direct edge and a saturated host path, but an idle
    gpu0-gpu2-gpu1 NVLink path exists: the planner must detour (and skip
    host staging)."""
    names = ["gpu0", "gpu1", "gpu2"]
    topo = ClusterTopology(
        [GPUNode(nm, A100_40G) for nm in names],
        nvlinks=[("gpu0", "gpu2", NVLINK_A100_GBPS),
                 ("gpu2", "gpu1", NVLINK_A100_GBPS)],
    )
    planner = TransferPlanner(topo, saturation_depth=2)
    topo.planner = planner
    # saturate both host legs of the would-be staged path
    planner.submit(
        [TransferRequest("gpu0", HOST, GB, "snapshot", URGENCY_RT),
         TransferRequest(HOST, "gpu0", GB, "restore", URGENCY_RT),
         TransferRequest("gpu1", HOST, GB, "snapshot", URGENCY_RT),
         TransferRequest(HOST, "gpu1", GB, "restore", URGENCY_RT)],
        0.0,
    )
    plan = planner.submit_one(
        TransferRequest("gpu0", "gpu1", GB, "checkpoint", URGENCY_RT), 0.0
    )
    assert planner.detours == 1
    assert not plan.staged
    leg_links = [frozenset(name.split("<->")) for name, _ in plan.legs]
    assert leg_links == [frozenset(("gpu0", "gpu2")),
                         frozenset(("gpu2", "gpu1"))]
    # only the two restores stage in host DRAM; the detour parked nothing
    assert topo.host_staged_bytes(0.0) == 2 * GB


def test_speculative_deferred_under_storm_urgent_admitted():
    """Under heavy contention a speculative rebalance is deferred (``None``,
    retried next tick) while an RT restore with the *same* shape is
    admitted — urgency outranks speculation."""
    topo = homogeneous(2, A100_40G)
    planner = TransferPlanner(topo, defer_stretch=3.0)
    topo.planner = planner
    # six RT flights pile onto gpu0's host leg: any newcomer sees ~7x solo
    storm = [
        TransferRequest("gpu0", HOST, GB, "snapshot", URGENCY_RT)
        for _ in range(6)
    ]
    planner.submit(storm, 0.0)
    spec = planner.submit_one(
        TransferRequest("gpu0", HOST, GB, "checkpoint"), 0.0
    )
    assert spec is None
    assert planner.urgency_deferred == 1
    urgent = planner.submit_one(
        TransferRequest("gpu0", HOST, GB, "checkpoint", URGENCY_RESTORE), 0.0
    )
    assert urgent is not None


def test_window_admits_in_urgency_order():
    """Within one window the RT restore is priced before the speculative
    checkpoint regardless of submission order — it lands no later."""
    topo = homogeneous(2, A100_40G)
    planner = TransferPlanner(topo)
    topo.planner = planner
    plans = planner.submit(
        [TransferRequest("gpu0", "gpu1", GB, "checkpoint"),      # speculative
         TransferRequest(HOST, "gpu1", GB, "restore", URGENCY_RT)],
        0.0,
    )
    assert plans[1] is not None
    if plans[0] is not None:
        assert plans[1].arrival_us <= plans[0].arrival_us


# --------------------------------------------------------------------------
# peer-fetch pressure feedback: the scavenger always progresses
# --------------------------------------------------------------------------


class _StubPool:
    def __init__(self, capacity, used):
        self.capacity = capacity
        self.used = used


class _StubCore:
    """Just enough of SimCore for linger_retention_ok's zero-headroom
    fast path (which must answer before ever consulting the state view)."""

    def __init__(self, capacity, used):
        self.pool = _StubPool(capacity, used)
        self.page_size = PAGE

    def state_view(self):  # pragma: no cover - must not be reached
        raise AssertionError(
            "zero-headroom check consulted the state view: the scavenger "
            "would block on demand accounting"
        )


def _check_scavenger_progress(seed):
    """Property: whatever the topology, entry shape, or byte counts, a
    holder with zero free headroom is NEVER asked to retain a linger copy —
    eviction always makes progress, so the scavenger cannot deadlock on a
    transfer that is itself waiting for the eviction."""
    rnd = random.Random(seed)
    topo = _random_topology(rnd)
    planner = TransferPlanner(topo)
    gpus = [g.name for g in topo.gpus]
    src, dst = rnd.sample(gpus, 2)
    pages = rnd.randint(0, 4096)
    entry = LingerEntry(
        task_id=rnd.randrange(100), src=src, dst=dst,
        runs=[(0, pages)] if pages else [],
        arrival_us=rnd.uniform(0.0, 1e6),
    )
    capacity = rnd.randint(1, 1 << 16)
    over = rnd.randint(0, 64)
    core = _StubCore(capacity, capacity + over)  # zero (or negative) headroom
    assert planner.linger_retention_ok(entry, core, rnd.uniform(0, 1e6)) is False
    # and the release is observable to the probe
    assert entry.task_id in planner._scavenged


if st is not None:

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 10**6))
    def test_property_scavenger_always_progresses(seed):
        _check_scavenger_progress(seed)

else:

    @pytest.mark.parametrize("seed", range(25))
    def test_property_scavenger_always_progresses(seed):
        _check_scavenger_progress(6151 * seed + 3)


def test_retention_free_when_holder_has_headroom():
    """With headroom and a live NVLink edge, a costless retention is kept
    (overflow <= 0 short-circuits before any rate arithmetic)."""
    topo = homogeneous(2, A100_40G, nvlink_gbps=NVLINK_A100_GBPS)

    class _Core(_StubCore):
        def state_view(self):
            class _St:
                policy = RoundRobinPolicy(5_000.0)
                waiting_pages = 0
                active = {}
                helpers = {}
                page_size = PAGE
            return _St()

    planner = TransferPlanner(topo)
    entry = LingerEntry(1, "gpu0", "gpu1", [(0, 8)], 0.0)
    core = _Core(capacity=1024, used=100)
    assert planner.linger_retention_ok(entry, core, 0.0) is True


def test_retention_denied_without_peer_path():
    """A downed NVLink edge makes the copy worthless to its target: the
    scavenger gets it back immediately."""
    topo = homogeneous(2, A100_40G, nvlink_gbps=NVLINK_A100_GBPS)
    topo.degrade("gpu0", "gpu1", 0.0)
    planner = TransferPlanner(topo)
    entry = LingerEntry(1, "gpu0", "gpu1", [(0, 8)], 0.0)
    core = _StubCore(capacity=1024, used=100)
    assert planner.linger_retention_ok(entry, core, 0.0) is False
    assert planner.pressure_scavenged == 1
