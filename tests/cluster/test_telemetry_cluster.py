"""Cluster telemetry: the telemetry-off bit-for-bit pin (plain and faulted
fleets), the faulted 4-GPU trace acceptance criterion (valid Chrome trace,
per-GPU tracks, link counter tracks, exact stall conservation), the
finish-hook linger reap regression, the 1-GPU fleet percentile-convention
pin, and the ``ClusterReport`` JSON round-trip."""
import json

import pytest

from repro.cluster import (
    ClusterReport,
    FaultEvent,
    FaultInjector,
    PeerPrefetchFabric,
    PlacementPolicy,
    homogeneous,
    simulate_cluster,
)
from repro.core.hardware import NVLINK_A100_GBPS, RTX5080
from repro.core.scheduler import RoundRobinPolicy
from repro.core.simulator import SimCore, TaskArrival
from repro.serving import (
    MSchedAdmission,
    Request,
    SLOSpec,
    ServedRequestTask,
    poisson_trace,
    serve_trace,
)
from repro.telemetry import (
    STALL_CATEGORIES,
    TRACK_CLUSTER,
    Telemetry,
    validate_trace,
)

ARCH = "qwen3-1.7b"
PAGE = 1 << 20
NV = NVLINK_A100_GBPS
SLO = SLOSpec(ttft_us=3_000_000.0, tpot_us=100_000.0)


def _trace(rate=6.0, duration=1.5, seed=3, output_mean=24):
    return poisson_trace(
        rate, duration, seed=seed, tenants=(ARCH,), prompt_mean=64,
        output_mean=output_mean, max_output=2 * output_mean,
    )


def _rec_tuple(r):
    return (
        r.task_id, r.arrival_us, r.admitted_us, r.first_iter_us,
        r.finished_us, r.iterations_done, r.total_iterations, r.rejected,
    )


def _fingerprint(rep):
    m = rep.merged
    return (
        m.sim_us, m.faults, m.migrated_bytes, m.switches, m.control_us,
        m.hbm_used_pages,
        tuple(_rec_tuple(r) for r in m.requests),
        len(rep.migrations), len(rep.peer_fetches), rep.peer_fetch_bytes,
        rep.linger_reclaimed_pages, rep.linger_finish_reaped,
        rep.faults_applied, len(rep.recoveries), rep.checkpoints,
        rep.shed_requests, rep.lost_requests,
    )


class Pin0(PlacementPolicy):
    name = "pin0"

    def place(self, prog, arrival_us, cores):
        return 0


def _cluster(telemetry=None, n=2, faults=None, trace=None, **kw):
    kw.setdefault("rebalance_period_us", 400_000.0)
    kw.setdefault("rebalance_threshold", 0.4)
    return simulate_cluster(
        trace if trace is not None else _trace(),
        homogeneous(n, RTX5080, capacity_bytes=3 << 30, nvlink_gbps=NV),
        backend="msched", placement=Pin0(),
        admission_factory=lambda i: MSchedAdmission(headroom=0.9),
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE, slo=SLO, faults=faults, telemetry=telemetry, **kw
    )


# --------------------------------------------------------------------------
# Telemetry-off bit-for-bit equivalence
# --------------------------------------------------------------------------


def test_cluster_run_unperturbed_by_tracing():
    off = _cluster(telemetry=None)
    on = _cluster(telemetry=Telemetry(sample_stride=1))
    assert _fingerprint(off) == _fingerprint(on)


def test_faulted_cluster_run_unperturbed_by_tracing():
    def inj():
        return FaultInjector([
            FaultEvent(500_000.0, "gpu_fail", gpu="gpu0"),
            FaultEvent(1_200_000.0, "gpu_recover", gpu="gpu0"),
            FaultEvent(600_000.0, "link_degrade", link=("gpu0", "gpu1"),
                       factor=0.5),
            FaultEvent(900_000.0, "link_restore", link=("gpu0", "gpu1")),
        ])

    off = _cluster(telemetry=None, faults=inj(),
                   checkpoint_period_us=300_000.0, drain_factor=20.0)
    on = _cluster(telemetry=Telemetry(sample_stride=1), faults=inj(),
                  checkpoint_period_us=300_000.0, drain_factor=20.0)
    assert _fingerprint(off) == _fingerprint(on)


# --------------------------------------------------------------------------
# The acceptance criterion: faulted 4-GPU fleet -> valid trace
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def faulted_4gpu():
    # long-running tasks (200 output tokens) so they straddle checkpoint
    # boundaries and the gpu0 outage interrupts work in flight
    tel = Telemetry(sample_stride=1)
    inj = FaultInjector([
        FaultEvent(700_000.0, "gpu_fail", gpu="gpu0"),
        FaultEvent(1_500_000.0, "gpu_recover", gpu="gpu0"),
        FaultEvent(800_000.0, "link_degrade", link=("gpu0", "gpu2"),
                   factor=0.25),
    ])
    rep = _cluster(
        telemetry=tel, n=4, faults=inj,
        trace=_trace(rate=2.0, duration=1.5, output_mean=200),
        checkpoint_period_us=300_000.0, drain_factor=20.0,
    )
    return tel, rep


def test_faulted_4gpu_trace_validates(faulted_4gpu, tmp_path):
    tel, rep = faulted_4gpu
    tel.write_chrome(tmp_path / "f.trace")
    doc = json.loads((tmp_path / "f.trace").read_text())
    assert validate_trace(doc) == []
    tracks = {
        ev["args"]["name"] for ev in doc["traceEvents"] if ev["ph"] == "M"
    }
    # one track per GPU, the cluster scope, and at least one link track
    assert {"gpu0", "gpu1", "gpu2", "gpu3", TRACK_CLUSTER} <= tracks
    assert any(t.startswith("link:") for t in tracks)
    # link counter probes rode along
    assert any(k.startswith("link:") and k.endswith("/inflight_bytes")
               for k in doc["probes"])
    assert any(k.startswith("link:") and k.endswith("/sharers")
               for k in doc["probes"])
    assert any(k.endswith("/hbm_used_pages") for k in doc["probes"])
    assert "host/staged_bytes" in doc["probes"]


def test_faulted_4gpu_event_coverage(faulted_4gpu):
    tel, rep = faulted_4gpu
    names = {e.name for e in tel.events}
    assert {"switch", "admission", "finish", "rebalance_tick",
            "gpu_fail", "gpu_recover", "checkpoint"} <= names
    if rep.recoveries:
        assert "recovery" in names
    if rep.migrations:
        assert {"migration_plan", "migration_land"} & names
    ticks = [e for e in tel.events if e.name == "rebalance_tick"]
    assert ticks and all(e.track == TRACK_CLUSTER for e in ticks)
    fails = [e for e in tel.events if e.name == "gpu_fail"]
    assert [e.track for e in fails] == ["gpu0"]


def test_faulted_4gpu_stall_conservation_exact(faulted_4gpu):
    tel, rep = faulted_4gpu
    bd = tel.stall_breakdown()
    finished = [
        r for r in rep.merged.requests
        if r.finished_us is not None and not r.rejected
    ]
    assert len(bd) == len(finished)
    for rec in finished:
        row = bd[rec.task_id]
        assert row["wall_us"] == pytest.approx(
            rec.finished_us - rec.arrival_us
        )
        attributed = sum(row[cat] for cat in STALL_CATEGORIES)
        assert attributed == pytest.approx(
            row["non_compute_us"], rel=1e-9, abs=1e-6
        )
    totals = tel.stall_totals()
    if rep.recoveries:
        assert totals["recovery"] > 0.0


# --------------------------------------------------------------------------
# Finish-hook linger reap (the silent-drop regression)
# --------------------------------------------------------------------------


def _serving_core(name, req_id=0, cap=4 << 30):
    req = Request(req_id, ARCH, 1_000.0, prompt_tokens=64,
                  output_tokens=64, slo_class="be")
    events = [
        TaskArrival(req.arrival_us,
                    ServedRequestTask(req_id, req, page_size=PAGE))
    ]
    return SimCore(
        [], RTX5080, "msched", capacity_bytes=cap,
        policy=RoundRobinPolicy(350_000.0), task_events=events,
        page_size=PAGE, prepopulate=False, name=name,
        profile_set=[ServedRequestTask(10_000_000 + req_id, req,
                                       page_size=PAGE)],
    )


def test_finish_hook_reaps_inflight_linger():
    """A task that finishes while its lazy-migration manifest is still in
    flight must have its linger copy reaped at retirement, not leak until
    the next rebalance tick."""
    topo = homogeneous(2, RTX5080, capacity_bytes=4 << 30, nvlink_gbps=NV)
    c0, c1 = _serving_core("gpu0", 0), _serving_core("gpu1", 1)
    fabric = PeerPrefetchFabric(topo, [c0, c1])
    fabric.wire()
    assert c0.finish_hook is not None and c1.finish_hook is not None

    # fake a lazy migration gpu0 -> gpu1 whose manifest lands at t=1000:
    # 10 pages linger on gpu0, hinted in the directory
    span = (0, 10)
    c0.pool.register_task(42, span)
    c0.pool.populate_runs([span])
    c0.lingering.add(42)
    fabric.directory.record(42, "gpu0", "gpu1", [span], arrival_us=1_000.0)
    used_before = c0.pool.used

    # the task finishes on gpu1 at t=500 — mid-flight
    c1.finish_hook(42, 500.0)
    assert fabric.directory.get(42) is None
    assert fabric.finish_reaped == 10
    assert fabric.reclaimed_pages == 10
    assert c0.pool.used == used_before - 10
    assert 42 not in c0.lingering
    # idempotent: a second finish (or the next reap tick) finds nothing
    c1.finish_hook(42, 600.0)
    assert fabric.finish_reaped == 10


def test_finish_reap_counted_in_report():
    rep = _cluster(telemetry=None)
    assert rep.linger_finish_reaped >= 0
    assert rep.to_row()["linger_finish_reaped"] == rep.linger_finish_reaped


# --------------------------------------------------------------------------
# Percentile-convention pin: 1-GPU fleet == single core
# --------------------------------------------------------------------------


def test_single_gpu_fleet_percentiles_match_single_core():
    """The cluster aggregation layer and the single-core serving path share
    one percentile convention: a 1-GPU fleet's merged scoreboard equals the
    plain ``serve_trace`` scoreboard on the same trace."""
    tr = _trace()
    solo = serve_trace(
        tr, RTX5080, backend="msched", capacity_bytes=3 << 30,
        admission=MSchedAdmission(headroom=0.9),
        policy=RoundRobinPolicy(350_000.0), page_size=PAGE, slo=SLO,
    )
    fleet = simulate_cluster(
        tr, homogeneous(1, RTX5080, capacity_bytes=3 << 30),
        backend="msched", placement=Pin0(),
        admission_factory=lambda i: MSchedAdmission(headroom=0.9),
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE, slo=SLO,
    )
    st = fleet.stats
    assert st.ttft_p50_us == solo.ttft_p50_us
    assert st.ttft_p99_us == solo.ttft_p99_us
    assert st.tpot_p50_us == solo.tpot_p50_us
    assert st.tpot_p99_us == solo.tpot_p99_us
    assert st.latency_p99_us == solo.latency_p99_us
    assert st.goodput_per_s == solo.goodput_per_s
    assert st.throughput_per_s == solo.throughput_per_s


# --------------------------------------------------------------------------
# ClusterReport JSON round-trip
# --------------------------------------------------------------------------


def test_cluster_report_json_roundtrip():
    rep = _cluster(telemetry=None)
    doc = json.loads(json.dumps(rep.to_json()))
    back = ClusterReport.from_json(doc)
    assert back.to_row() == rep.to_row()
    assert _fingerprint(back) == _fingerprint(rep)
    assert [_rec_tuple(r) for g in back.per_gpu for r in g.result.requests] \
        == [_rec_tuple(r) for g in rep.per_gpu for r in g.result.requests]
    # a second round-trip is a fixed point
    assert back.to_json() == rep.to_json()
    with pytest.raises(ValueError):
        ClusterReport.from_json({"schema": "not-a-report"})


def test_cluster_report_v2_roundtrips_control_counters():
    rep = _cluster(telemetry=None)
    doc = rep.to_json()
    assert doc["schema"] == "cluster-report-v2"
    # exercise the v2 fields with non-default values
    doc["journal_len"] = 41
    doc["journal_replays"] = 2
    doc["coordinator_crashes"] = 2
    doc["deadline_misses"] = 3
    doc["preemptions"] = 5
    doc["deadline_sheds"] = 1
    back = ClusterReport.from_json(json.loads(json.dumps(doc)))
    assert (
        back.journal_len, back.journal_replays, back.coordinator_crashes,
        back.deadline_misses, back.preemptions, back.deadline_sheds,
    ) == (41, 2, 2, 3, 5, 1)
    assert back.to_json() == doc


def test_cluster_report_reads_v1_documents():
    """A v1 document (written before the control plane existed) still
    loads: the control counters default to zero."""
    rep = _cluster(telemetry=None)
    doc = rep.to_json()
    doc["schema"] = "cluster-report-v1"
    for k in (
        "journal_len", "journal_replays", "coordinator_crashes",
        "deadline_misses", "preemptions", "deadline_sheds",
    ):
        del doc[k]
    back = ClusterReport.from_json(doc)
    assert back.journal_len == 0 and back.coordinator_crashes == 0
    assert back.deadline_misses == 0 and back.preemptions == 0
    # re-serialization upgrades to the current schema
    assert back.to_json()["schema"] == "cluster-report-v2"


def test_cluster_report_rejects_unknown_schema():
    rep = _cluster(telemetry=None)
    doc = rep.to_json()
    doc["schema"] = "cluster-report-v99"
    with pytest.raises(ValueError, match="cluster-report-v1"):
        ClusterReport.from_json(doc)
    with pytest.raises(ValueError, match="unknown cluster-report schema"):
        ClusterReport.from_json({})
