"""Cluster engine: the pinned 1-GPU equivalence invariant, multi-GPU
dispatch, and the inter-GPU migration path (steal + checkpointed move)."""
import pytest

from repro.cluster import (
    MSchedPlacement,
    PlacementPolicy,
    Rebalancer,
    ResumedTask,
    homogeneous,
    simulate_cluster,
)
from repro.core.hardware import RTX5080
from repro.core.scheduler import RoundRobinPolicy
from repro.core.simulator import SimCore, TaskArrival, simulate
from repro.core.workloads import LLMDecodeTask
from repro.serving import (
    AlwaysAdmit,
    MSchedAdmission,
    Request,
    ServedRequestTask,
    poisson_trace,
    serve_trace,
)

ARCH = "qwen3-1.7b"
PAGE = 1 << 20


def _trace(rate=4.0, duration=1.2, seed=11, output_mean=8):
    return poisson_trace(
        rate, duration, seed=seed, tenants=(ARCH,), prompt_mean=64,
        output_mean=output_mean, max_output=2 * output_mean,
    )


def _rec_tuple(r):
    return (
        r.task_id, r.arrival_us, r.admitted_us, r.first_iter_us,
        r.finished_us, r.iterations_done, r.total_iterations, r.rejected,
    )


class PinFirst(PlacementPolicy):
    """Worst-case skew: every arrival lands on gpu0."""

    name = "pin0"

    def place(self, prog, arrival_us, cores):
        return 0


# --------------------------------------------------------------------------
# The pinned equivalence invariant
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["um", "msched", "ideal", "suv"])
def test_single_gpu_cluster_reproduces_simulate(backend):
    """A 1-GPU cluster run — real event loop, per-arrival placement and
    injection — is bit-for-bit the single-GPU ``simulate()`` on the same
    trace, for every memory backend."""
    cap = 4 << 30
    quantum = 2_000.0 if backend == "um" else 350_000.0
    mk_admission = (
        (lambda: MSchedAdmission(headroom=0.9))
        if backend in ("msched", "ideal")
        else (lambda: AlwaysAdmit())
    )
    single = serve_trace(
        _trace(), RTX5080, backend=backend, capacity_bytes=cap,
        admission=mk_admission(), policy=RoundRobinPolicy(quantum),
        page_size=PAGE,
    )
    rep = simulate_cluster(
        _trace(), homogeneous(1, RTX5080, capacity_bytes=cap),
        backend=backend, placement="roundrobin",
        admission_factory=lambda i: mk_admission(),
        policy_factory=lambda i: RoundRobinPolicy(quantum),
        page_size=PAGE,
    )
    a, b = single.result, rep.merged
    assert a.sim_us == b.sim_us
    assert a.switches == b.switches
    assert a.control_us == b.control_us
    assert a.faults == b.faults
    assert a.migrated_bytes == b.migrated_bytes
    assert a.hbm_used_pages == b.hbm_used_pages
    assert a.hbm_freed_pages == b.hbm_freed_pages
    assert [_rec_tuple(r) for r in a.requests] == [
        _rec_tuple(r) for r in b.requests
    ]
    assert {
        t: (s.completions, s.commands, s.busy_us)
        for t, s in a.per_task.items()
    } == {
        t: (s.completions, s.commands, s.busy_us)
        for t, s in b.per_task.items()
    }
    # the scoreboard built from merged records matches the serve report
    assert rep.stats.goodput_per_s == single.goodput_per_s
    assert rep.stats.ttft_p99_us == single.ttft_p99_us


def test_single_gpu_paged_pool_also_matches():
    """The equivalence holds on the per-page reference pool too."""
    cap = 3 << 30
    tr = _trace(rate=3.0, duration=0.8)
    single = serve_trace(
        tr, RTX5080, backend="msched", capacity_bytes=cap,
        admission=MSchedAdmission(), policy=RoundRobinPolicy(350_000.0),
        page_size=PAGE, pool="paged",
    )
    rep = simulate_cluster(
        _trace(rate=3.0, duration=0.8),
        homogeneous(1, RTX5080, capacity_bytes=cap),
        backend="msched", placement="msched",
        admission_factory=lambda i: MSchedAdmission(),
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE, pool="paged",
    )
    assert single.result.sim_us == rep.merged.sim_us
    assert [_rec_tuple(r) for r in single.result.requests] == [
        _rec_tuple(r) for r in rep.merged.requests
    ]


# --------------------------------------------------------------------------
# Multi-GPU dispatch
# --------------------------------------------------------------------------


def test_two_gpus_split_and_account():
    # long enough decodes that requests overlap: the count-balancer must
    # actually alternate devices
    rep = simulate_cluster(
        _trace(rate=8.0, output_mean=32),
        homogeneous(2, RTX5080, capacity_bytes=4 << 30),
        backend="msched", placement="leastloaded",
        admission_factory=lambda i: MSchedAdmission(headroom=0.9),
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE,
    )
    assert rep.n_gpus == 2
    assert sum(g.placed for g in rep.per_gpu) == rep.stats.n_requests
    assert all(g.placed > 0 for g in rep.per_gpu)  # load actually split
    per_gpu_finished = sum(
        len(g.result.finished_requests()) for g in rep.per_gpu
    )
    assert per_gpu_finished == rep.stats.n_finished
    assert rep.stats.n_finished == rep.stats.n_requests  # ample capacity
    assert rep.merged.switches == sum(g.result.switches for g in rep.per_gpu)


def test_cluster_goodput_beats_one_overloaded_gpu():
    """Same total load: a 2-GPU fleet with placement beats the same requests
    crammed onto one GPU of half the total capacity's pressure."""
    tr_args = dict(rate=6.0, duration=1.5, seed=5)
    cap = 3 << 30
    single = serve_trace(
        _trace(**tr_args), RTX5080, backend="msched", capacity_bytes=cap,
        admission=MSchedAdmission(headroom=0.9),
        policy=RoundRobinPolicy(350_000.0), page_size=PAGE,
    )
    rep = simulate_cluster(
        _trace(**tr_args), homogeneous(2, RTX5080, capacity_bytes=cap),
        backend="msched", placement="msched",
        admission_factory=lambda i: MSchedAdmission(headroom=0.9),
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE,
    )
    assert rep.stats.goodput_per_s >= single.goodput_per_s
    assert rep.stats.ttft_p99_us <= single.ttft_p99_us


# --------------------------------------------------------------------------
# Inter-GPU migration
# --------------------------------------------------------------------------


def test_rebalancer_migrates_off_skewed_gpu(tmp_path):
    """All arrivals pinned to gpu0; the rebalancer moves work to the idle
    gpu1 — through the real checkpoint format — and the merged records show
    one coherent lifetime per migrated request."""
    rep = simulate_cluster(
        _trace(rate=6.0, duration=1.5, seed=3, output_mean=24),
        homogeneous(2, RTX5080, capacity_bytes=4 << 30),
        backend="msched", placement=PinFirst(),
        admission_factory=lambda i: MSchedAdmission(headroom=0.9),
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE,
        rebalance_period_us=200_000.0, rebalance_threshold=0.3,
        stage_dir=str(tmp_path),
    )
    assert rep.migrations, "skewed load must trigger migration"
    assert all(m.src == "gpu0" and m.dst == "gpu1" for m in rep.migrations)
    assert rep.stats.n_finished == rep.stats.n_requests
    # something actually ran on the target
    gpu1 = rep.per_gpu[1].result
    assert gpu1.total_completions() > 0
    # fragments merged into one record per request (no duplicate ids)
    tids = [r.task_id for r in rep.merged.requests]
    assert len(tids) == len(set(tids))
    moved = [m for m in rep.migrations if m.kind == "checkpoint"]
    stolen = [m for m in rep.migrations if m.kind == "steal"]
    assert moved or stolen
    for m in moved:
        rec = next(r for r in rep.merged.requests if r.task_id == m.task_id)
        assert rec.finished_us is not None
        assert rec.meta.get("fragments", 1) == 2
        assert rec.meta.get("migrated_from") == "gpu0"
    # checkpoints really hit the stage dir when a running task moved
    if moved:
        assert any(p.name.startswith("step_") for p in tmp_path.iterdir())


def test_steal_prefers_queued_candidates():
    """With a backlog queued behind admission control on gpu0 and gpu1 idle,
    rebalancing reroutes queued candidates (free) before checkpointing
    running tasks."""
    cap = 2 << 30  # roughly one active request fits
    # first tick at 300 ms: ~3 arrivals by then, so a backlog is queued
    # behind admission control when the rebalancer first looks
    rep = simulate_cluster(
        _trace(rate=10.0, duration=1.0, seed=9, output_mean=32),
        homogeneous(2, RTX5080, capacity_bytes=cap),
        backend="msched", placement=PinFirst(),
        admission_factory=lambda i: MSchedAdmission(headroom=0.9),
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE,
        rebalance_period_us=300_000.0, rebalance_threshold=0.3,
    )
    kinds = [m.kind for m in rep.migrations]
    assert "steal" in kinds
    # rerouted requests complete on gpu1
    assert len(rep.per_gpu[1].result.finished_requests()) > 0


def test_simcore_eject_midrun():
    """Ejection tears down scheduler + pool state without finishing the
    request; the ejected snapshot carries the resident working set."""
    # one long-decoding request (400 output tokens ≈ 1 s of decode): still
    # mid-flight when we eject at 200 ms
    req = Request(0, ARCH, 1_000.0, prompt_tokens=64, output_tokens=400)
    events = [TaskArrival(req.arrival_us, ServedRequestTask(0, req, page_size=PAGE))]
    core = SimCore(
        [], RTX5080, "msched", capacity_bytes=4 << 30,
        policy=RoundRobinPolicy(350_000.0), task_events=events,
        page_size=PAGE, prepopulate=False,
        profile_set=[ServedRequestTask(10_000_000, req, page_size=PAGE)],
    )
    core.run(200_000.0, final=False)
    assert core.tasks, "a task should be active mid-trace"
    tid = next(iter(core.tasks))
    used_before = core.pool.used
    ej = core.eject(tid)
    assert tid not in core.tasks and tid not in core.helpers
    assert ej.program.task_id == tid
    assert ej.resident_runs, "a running msched task has resident pages"
    assert core.pool.used == used_before - ej.working_set_pages()
    rec = core.rec_by_tid[tid]
    assert rec.finished_us is None and "ejected_us" in rec.meta
    # the continuation resumes past the completed prefix
    cont = ResumedTask(ej.program, ej.completed)
    assert cont.task_id == tid
    assert cont.total_iterations == ej.program.total_iterations - ej.completed
    assert cont.space is ej.program.space


def test_eject_then_return_accumulates_stats():
    """A task ejected and later re-admitted to the *same* core (ping-pong
    rebalancing) must be admissible again, warm-start from its checkpointed
    runs, and have both visits' work summed in per_task."""
    req = Request(0, ARCH, 1_000.0, prompt_tokens=64, output_tokens=300)
    events = [TaskArrival(req.arrival_us, ServedRequestTask(0, req, page_size=PAGE))]
    core = SimCore(
        [], RTX5080, "msched", capacity_bytes=4 << 30,
        policy=RoundRobinPolicy(350_000.0), task_events=events,
        page_size=PAGE, prepopulate=False,
        profile_set=[ServedRequestTask(10_000_000, req, page_size=PAGE)],
    )
    core.run(200_000.0, final=False)
    ej = core.eject(0)
    first_visit = ej.completed
    assert 0 < first_visit < 300
    cont = ResumedTask(ej.program, ej.completed)
    core.inject(
        TaskArrival(core.t + 10_000.0, cont), warm_runs=ej.resident_runs
    )
    core.run(10_000_000.0, final=True)
    res = core.result()
    assert res.per_task[0].completions == 300  # both visits summed
    frags = [r for r in res.requests if r.task_id == 0]
    assert len(frags) == 2
    assert frags[0].finished_us is None and frags[1].finished_us is not None
    assert sum(r.iterations_done for r in frags) == 300


def test_resumed_task_offsets_iterations():
    inner = LLMDecodeTask(3, arch=ARCH, page_size=PAGE, start_len=16)
    inner.total_iterations = 10
    cont = ResumedTask(inner, 4)
    assert cont.total_iterations == 6
    # iteration 0 of the continuation is iteration 4 of the inner program:
    # the attention command sees the grown KV slice
    attn = [c for c in cont.iteration(0) if c.name == "llm_attn"]
    attn_inner = [c for c in inner.iteration(4) if c.name == "llm_attn"]
    assert attn[0].args[2] == attn_inner[0].args[2] == inner.seq_len(4)
