"""NVLink peer-to-peer working-set prefetch and cluster-wide OPT eviction:
directory bookkeeping, source-tier pricing under link contention, host
fallback after source-side eviction, the lazy p2p migration path, the
migration retry protocol, and the peer-less bit-for-bit equivalence pin."""
import pytest

from repro.cluster import (
    PageDirectory,
    PeerPrefetchFabric,
    PlacementPolicy,
    Rebalancer,
    ResumedTask,
    homogeneous,
    simulate_cluster,
)
from repro.cluster.topology import HOST
from repro.core.hardware import NVLINK_A100_GBPS, RTX5080
from repro.core.memory_manager import Coordinator
from repro.core.migration import PeerGroup, TieredMigration, plan_population_runs
from repro.core.pages import intersect_runs, run_page_count, subtract_runs
from repro.core.planner import partition_source_tiers
from repro.core.scheduler import RoundRobinPolicy
from repro.core.simulator import AdmissionController, SimCore, TaskArrival
from repro.core.timeline import TaskTimeline, TimelineEntry
from repro.serving import (
    MSchedAdmission,
    Request,
    ServedRequestTask,
    poisson_trace,
)

ARCH = "qwen3-1.7b"
PAGE = 1 << 20
NV = NVLINK_A100_GBPS


def _trace(rate=6.0, duration=1.5, seed=3, output_mean=24):
    return poisson_trace(
        rate, duration, seed=seed, tenants=(ARCH,), prompt_mean=64,
        output_mean=output_mean, max_output=2 * output_mean,
    )


def _rec_tuple(r):
    return (
        r.task_id, r.arrival_us, r.admitted_us, r.first_iter_us,
        r.finished_us, r.iterations_done, r.total_iterations, r.rejected,
    )


class Pin0(PlacementPolicy):
    name = "pin0"

    def place(self, prog, arrival_us, cores):
        return 0


def _serving_core(name, req_id=0, output_tokens=400, cap=4 << 30):
    """One msched core with a single long-decoding request admitted."""
    req = Request(req_id, ARCH, 1_000.0, prompt_tokens=64,
                  output_tokens=output_tokens)
    events = [
        TaskArrival(req.arrival_us, ServedRequestTask(req_id, req, page_size=PAGE))
    ]
    return SimCore(
        [], RTX5080, "msched", capacity_bytes=cap,
        policy=RoundRobinPolicy(350_000.0), task_events=events,
        page_size=PAGE, prepopulate=False, name=name,
        profile_set=[ServedRequestTask(10_000_000 + req_id, req, page_size=PAGE)],
    )


# --------------------------------------------------------------------------
# run helpers / directory bookkeeping
# --------------------------------------------------------------------------


def test_run_set_arithmetic():
    runs = [(0, 10), (20, 30)]
    other = [(5, 8), (25, 40)]
    assert intersect_runs(runs, other) == [(5, 8), (25, 30)]
    assert subtract_runs(runs, other) == [(0, 5), (8, 10), (20, 25)]
    # order of the first argument is preserved
    assert intersect_runs([(20, 30), (0, 10)], other) == [(25, 30), (5, 8)]


def test_partition_source_tiers():
    requested = [(0, 10), (20, 26)]
    lingered = [(2, 8), (20, 30)]  # sorted disjoint
    # the peer pool has since evicted (4, 6) and (22, 24)
    missing = lambda runs: intersect_runs(runs, [(4, 6), (22, 24)])
    peer, host, fresh = partition_source_tiers(requested, lingered, missing)
    assert peer == [(2, 4), (6, 8), (20, 22), (24, 26)]
    assert host == [(4, 6), (22, 24)]  # lingered but evicted: host round-trip
    assert fresh == [(0, 2), (8, 10)]  # never lingered anywhere
    total = run_page_count(peer) + run_page_count(host) + run_page_count(fresh)
    assert total == run_page_count(requested)


def test_page_directory_bookkeeping():
    d = PageDirectory()
    d.record(7, "gpu0", "gpu1", [(0, 10), (20, 30)], arrival_us=5.0)
    assert d.get(7).pages() == 20
    assert [e.task_id for e in d.on_gpu("gpu0")] == [7]
    assert list(d.on_gpu("gpu1")) == []
    d.retarget(7, "gpu2")
    assert d.get(7).dst == "gpu2"
    d.consume(7, [(0, 10)])
    assert d.get(7).runs == [(20, 30)]
    d.consume(7, [(20, 30)])  # emptied entries are forgotten
    assert d.get(7) is None and len(d) == 0


def test_demote_runs_head_order():
    from repro.core.hbm import HBMPool

    pool = HBMPool(16)
    for p in range(8):
        pool.populate(p)
    pool.demote_runs([(2, 4), (6, 7)])
    # demoted pages lead the eviction order, ascending run order
    assert pool.eviction_order()[:3] == [2, 3, 6]
    assert pool.resident_count() == 8


# --------------------------------------------------------------------------
# tiered migration pricing
# --------------------------------------------------------------------------


def test_tiered_migration_prices_peer_tier_at_nvlink_rate():
    host = plan_population_runs(RTX5080, [(0, 64)], 0, True, PAGE)
    rate = NV * 1e3  # bytes/us
    tiered = TieredMigration(host, [PeerGroup("gpu1", [(100, 164)], rate)], PAGE)
    assert tiered.populate_bytes == 128 * PAGE
    assert tiered.peer_bytes == 64 * PAGE
    view = tiered.ready_view(base=1000.0)
    # last peer page lands after 64 pages at NVLink rate
    peer_last = view.max_ready([(163, 164)])
    assert peer_last == pytest.approx(1000.0 + 64 * PAGE / rate)
    # host pages follow the standard pipelined recurrence (far slower)
    host_last = view.max_ready([(63, 64)])
    assert host_last == pytest.approx(1000.0 + host.times[-1])
    assert peer_last < host_last
    assert view.global_max == pytest.approx(max(peer_last, host_last))
    assert tiered.total_us == pytest.approx(
        max(host.total_us, 64 * PAGE / rate)
    )


def test_cluster_opt_order_merges_fleet_next_use():
    """The madvise walk interleaves foreign lingering runs by fleet next-use:
    runs a peer needs between local slices end up protected accordingly, and
    without a cluster view the order is exactly ``reversed(groups)``."""
    from repro.core.hbm import HBMPool

    coord = Coordinator(RTX5080, HBMPool(64), page_size=PAGE)
    timeline = TaskTimeline([TimelineEntry(0, 100.0), TimelineEntry(1, 100.0)])
    groups = [[(0, 4)], [(8, 12)]]
    assert list(coord._opt_order(timeline, groups, now=0.0)) == [
        [(8, 12)], [(0, 4)],
    ]
    # foreign runs needed at +50us (between the two local slices) are
    # madvised between them: protected more than slice 2, less than slice 1
    coord.cluster_view = lambda now: [(now + 50.0, [(20, 24)])]
    assert list(coord._opt_order(timeline, groups, now=1_000.0)) == [
        [(8, 12)], [(20, 24)], [(0, 4)],
    ]
    # foreign runs the fleet needs *last* are the first madvised (least
    # protected -> nearest the eviction head)
    coord.cluster_view = lambda now: [(now + 500.0, [(20, 24)])]
    assert list(coord._opt_order(timeline, groups, now=1_000.0)) == [
        [(20, 24)], [(8, 12)], [(0, 4)],
    ]


# --------------------------------------------------------------------------
# peer fetch through the fabric: pricing, contention, fallback
# --------------------------------------------------------------------------


def _linger_pair(cap_src=4 << 30):
    """src core with an ejected-but-lingering task; dst core idle; fabric
    wired over a 2-GPU NVLink topology."""
    topo = homogeneous(2, RTX5080, capacity_bytes=cap_src, nvlink_gbps=NV)
    src = _serving_core("gpu0", req_id=0)
    dst = _serving_core("gpu1", req_id=1, output_tokens=4)
    src.run(200_000.0, final=False)
    tid = next(iter(src.tasks))
    ej = src.eject(tid, linger=True)
    assert ej.resident_runs, "a running msched task has resident pages"
    fabric = PeerPrefetchFabric(topo, [src, dst])
    fabric.wire()
    fabric.directory.record(tid, "gpu0", "gpu1", ej.resident_runs, 200_000.0)
    return topo, src, dst, fabric, tid, ej


def test_linger_keeps_pages_resident_and_scavengeable():
    _, src, _, _, tid, ej = _linger_pair()
    ws = run_page_count(ej.resident_runs)
    assert src.pool.used == ws  # still resident (not freed)
    assert tid in src.lingering
    # demoted to the eviction-list head: the lingering pages are the first
    # victims under any local pressure
    head = src.pool.eviction_runs()[0]
    assert intersect_runs([head], ej.resident_runs) == [head]
    # reclaim is idempotent and guarded
    assert src.reclaim_linger(tid) == ws
    assert src.reclaim_linger(tid) == 0
    assert src.pool.used == 0


def test_peer_fetch_prices_nvlink_and_moves_pages():
    topo, src, dst, fabric, tid, ej = _linger_pair()
    ws = list(ej.resident_runs)
    n = run_page_count(ws)
    plan = fabric._plan_fetch(dst, tid, ws, 0, now=1_000.0)
    assert isinstance(plan, TieredMigration)
    [group] = plan.peers
    assert group.src == "gpu0"
    assert run_page_count(group.runs) == n
    # uncontended NVLink edge: full fluid share
    assert group.rate_bytes_per_us == pytest.approx(NV * 1e3, rel=1e-6)
    # the copy moved: source pool drained, directory entry consumed, and the
    # source's linger bookkeeping (flag + span) released with it
    assert src.pool.used == 0
    assert fabric.directory.get(tid) is None
    assert tid not in src.lingering
    assert tid not in src.pool._task_spans
    [fetch] = fabric.fetches
    assert fetch.pages == n and fetch.fallback_pages == 0
    # host tier is empty: nothing left to pipeline over PCIe
    assert plan.host.populate_bytes == 0


def test_concurrent_prefetch_and_migration_share_one_nvlink_edge():
    """A peer fetch planned while a migration transfer is in flight on the
    same NVLink edge gets the halved fluid share — both consumers go through
    one contention bookkeeping."""
    topo, src, dst, fabric, tid, ej = _linger_pair()
    nbytes = 1 << 30
    mig = topo.plan_transfer("gpu0", "gpu1", nbytes, now=1_000.0)
    assert mig is not None and not mig.staged
    plan = fabric._plan_fetch(dst, tid, list(ej.resident_runs), 0, now=1_000.0)
    [group] = plan.peers
    assert group.rate_bytes_per_us == pytest.approx(NV * 1e3 / 2, rel=1e-6)
    # and the fetch now occupies the edge too: a third transfer sees 3 sharers
    probe = topo.plan_transfer("gpu0", "gpu1", nbytes, now=1_000.0)
    dur = probe.arrival_us - probe.start_us
    assert dur == pytest.approx(nbytes / (NV * 1e3 / 3), rel=1e-6)


def test_peer_fetch_falls_back_to_host_when_source_evicted():
    """Sub-runs the source GPU evicted after the manifest shipped take the
    host-DRAM tier; a fully-evicted working set degrades to the plain host
    migration (plan is None -> standard path)."""
    topo, src, dst, fabric, tid, ej = _linger_pair()
    ws = list(ej.resident_runs)
    n = run_page_count(ws)
    # local pressure on gpu0 scavenges half the lingering set mid-stream
    lost = ws[: len(ws) // 2] or [ws[0]]
    src.pool.drop_runs(lost)
    n_lost = run_page_count(lost)
    plan = fabric._plan_fetch(dst, tid, ws, 0, now=1_000.0)
    assert isinstance(plan, TieredMigration)
    [group] = plan.peers
    assert run_page_count(group.runs) == n - n_lost
    assert fabric.fallback_pages == n_lost
    # the lost sub-runs ride the host pipeline instead
    assert plan.host.populate_bytes == n_lost * PAGE
    # source fully evicted -> no peer tier at all, caller takes host path
    fabric.directory.record(tid, "gpu0", "gpu1", ws, 0.0)
    src.pool.drop_runs(ws)
    assert fabric._plan_fetch(dst, tid, ws, 0, now=2_000.0) is None
    assert fabric.fallback_pages == n_lost + n
    # evicted sub-runs are consumed from the hint too: a later switch
    # re-requesting the same pages must not re-count the fallback
    assert fabric.directory.get(tid) is None
    assert fabric._plan_fetch(dst, tid, ws, 0, now=3_000.0) is None
    assert fabric.fallback_pages == n_lost + n


# --------------------------------------------------------------------------
# end-to-end: lazy p2p migration through simulate_cluster
# --------------------------------------------------------------------------


def test_nvlink_cluster_lazy_migration_end_to_end():
    """Skewed load on an NVLink pair: migrations ship manifests only
    (kind "p2p"), the target's extended context switches peer-fetch the
    working set, every request finishes, and no HBM leaks."""
    rep = simulate_cluster(
        _trace(rate=8.0, duration=2.0, output_mean=64),
        homogeneous(2, RTX5080, capacity_bytes=3 << 30, nvlink_gbps=NV),
        backend="msched", placement=Pin0(),
        admission_factory=lambda i: MSchedAdmission(headroom=0.9),
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE,
        rebalance_period_us=250_000.0, rebalance_threshold=0.3,
    )
    p2p = [m for m in rep.migrations if m.kind == "p2p"]
    assert p2p, "skewed NVLink fleet must use lazy p2p migration"
    # manifests are metadata-sized, not working-set-sized
    assert all(m.nbytes < 1 << 20 for m in p2p)
    assert [m for m in p2p if m.pages > 0], "a running task's WS lingered"
    assert rep.peer_fetches, "the target prefetched over NVLink"
    assert rep.peer_fetch_bytes > 0
    assert rep.stats.n_finished == rep.stats.n_requests
    assert rep.merged.hbm_used_pages == 0  # linger copies reaped
    tids = [r.task_id for r in rep.merged.requests]
    assert len(tids) == len(set(tids))


def test_peerless_topology_unaffected_by_peer_prefetch_flag():
    """The tentpole's bit-for-bit pin: on a PCIe-only fleet the peer-prefetch
    machinery is never constructed, so ``auto`` and ``off`` produce identical
    results — including under rebalancing (bulk checkpoint moves)."""
    kwargs = dict(
        backend="msched", placement=Pin0(),
        admission_factory=lambda i: MSchedAdmission(headroom=0.9),
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE,
        rebalance_period_us=200_000.0, rebalance_threshold=0.3,
    )
    a = simulate_cluster(
        _trace(), homogeneous(2, RTX5080, capacity_bytes=4 << 30),
        peer_prefetch="auto", **kwargs,
    )
    b = simulate_cluster(
        _trace(), homogeneous(2, RTX5080, capacity_bytes=4 << 30),
        peer_prefetch="off", **kwargs,
    )
    assert a.merged.sim_us == b.merged.sim_us
    assert a.merged.switches == b.merged.switches
    assert a.merged.control_us == b.merged.control_us
    assert a.merged.migrated_bytes == b.merged.migrated_bytes
    assert [_rec_tuple(r) for r in a.merged.requests] == [
        _rec_tuple(r) for r in b.merged.requests
    ]
    assert [m.kind for m in a.migrations] == [m.kind for m in b.migrations]
    assert not a.peer_fetches and not b.peer_fetches
    # and bulk moves stay bulk on peer-less fleets
    assert all(m.kind in ("steal", "checkpoint") for m in a.migrations)


def test_nvlink_fleet_with_prefetch_off_uses_bulk_path():
    rep = simulate_cluster(
        _trace(rate=8.0, duration=2.0, output_mean=64),
        homogeneous(2, RTX5080, capacity_bytes=3 << 30, nvlink_gbps=NV),
        backend="msched", placement=Pin0(),
        admission_factory=lambda i: MSchedAdmission(headroom=0.9),
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE,
        rebalance_period_us=250_000.0, rebalance_threshold=0.3,
        peer_prefetch="off",
    )
    assert all(m.kind in ("steal", "checkpoint") for m in rep.migrations)
    assert not rep.peer_fetches
    assert rep.stats.n_finished == rep.stats.n_requests


# --------------------------------------------------------------------------
# migration retry protocol (ROADMAP open item)
# --------------------------------------------------------------------------


class RejectAll(AdmissionController):
    def decide(self, prog, arrival_us, state):
        return "reject"


def test_rejected_continuation_returns_to_source_and_finishes():
    """A migrated continuation rejected by the target's admission deadline
    returns to the source instead of dropping its partially-executed work."""
    topo = homogeneous(2, RTX5080, capacity_bytes=4 << 30)
    src = _serving_core("gpu0", req_id=0, output_tokens=300)
    dst = _serving_core("gpu1", req_id=1, output_tokens=2)
    dst.admission = RejectAll()
    rb = Rebalancer(topo)
    rb.attach([src, dst])
    src.run(200_000.0, final=False)
    mv = rb._move_one(src, dst, 200_000.0)
    assert mv is not None and mv.kind == "checkpoint"
    assert 0 < mv.completed_iters < 300
    # drive the target: it rejects the continuation, the handler bounces it
    # back to the source, which completes the remaining iterations
    dst.run(10_000_000.0, final=True)
    src.run(20_000_000.0, final=True)
    retries = [e for e in rb.events if e.kind == "retry"]
    assert retries and retries[0].src == "gpu1" and retries[0].dst == "gpu0"
    frags = [r for r in src.records + dst.records if r.task_id == 0]
    assert not any(r.rejected for r in frags), "no fragment ends rejected"
    assert any(r.finished_us is not None for r in frags)
    assert sum(r.iterations_done for r in frags) == 300
    dst_frag = next(r for r in dst.records if r.task_id == 0)
    assert dst_frag.meta.get("retried_to") == "gpu0"


def test_fresh_arrival_rejections_still_shed():
    """Load shedding semantics are unchanged for work the cluster never
    executed: a fresh arrival rejected by admission stays rejected."""
    topo = homogeneous(2, RTX5080, capacity_bytes=4 << 30)
    src = _serving_core("gpu0", req_id=0, output_tokens=4)
    dst = _serving_core("gpu1", req_id=1, output_tokens=4)
    src.admission = RejectAll()
    rb = Rebalancer(topo)
    rb.attach([src, dst])
    src.run(10_000_000.0, final=True)
    rec = next(r for r in src.records if r.task_id == 0)
    assert rec.rejected
    assert not [e for e in rb.events if e.kind == "retry"]
    # a *stolen* fresh arrival (rerouted, never executed) also sheds: only
    # "migrated_from" continuations get the retry protocol
    req = Request(5, ARCH, 1_000.0, prompt_tokens=64, output_tokens=4)
    dst.admission = RejectAll()
    dst.inject(
        TaskArrival(
            dst.t + 1_000.0,
            ServedRequestTask(5, req, page_size=PAGE),
            meta={"rerouted_from": "gpu0"},
        )
    )
    dst.run(dst.t + 10_000_000.0, final=True)
    rec5 = next(r for r in dst.records if r.task_id == 5)
    assert rec5.rejected
    assert not [e for e in rb.events if e.kind == "retry"]


class QueueAll(AdmissionController):
    def decide(self, prog, arrival_us, state):
        return "queue"


def test_steal_beyond_nvlink_reach_harvests_linger_copy():
    """A lazily-migrated continuation stolen onward to a GPU with no NVLink
    edge to the linger source must carry its working set as warm runs (host
    staged, like any stolen checkpoint) — the source copy is withdrawn, not
    silently re-materialized from host DRAM later."""
    from repro.cluster.topology import ClusterTopology, GPUNode

    topo = ClusterTopology(
        [GPUNode(f"gpu{i}", RTX5080, 4 << 30) for i in range(3)],
        nvlinks=[("gpu0", "gpu1", NV)],  # partial mesh: gpu2 is PCIe-only
    )
    g0 = _serving_core("gpu0", req_id=0, output_tokens=300)
    g1 = _serving_core("gpu1", req_id=1, output_tokens=2)
    g2 = _serving_core("gpu2", req_id=2, output_tokens=2)
    g1.admission = QueueAll()  # the continuation queues behind admission
    fabric = PeerPrefetchFabric(topo, [g0, g1, g2])
    fabric.wire()
    rb = Rebalancer(topo, prefetch=fabric)
    rb.attach([g0, g1, g2])
    g0.run(200_000.0, final=False)
    mv = rb._move_one(g0, g1, 200_000.0)
    assert mv is not None and mv.kind == "p2p"
    assert fabric.directory.get(0) is not None
    linger_pages = g0.pool.used
    assert linger_pages > 0
    # the continuation lands and queues on gpu1; steal it onward to gpu2
    g1.run(mv.arrival_us + 1_000.0, final=False)
    assert g1.waiting, "continuation must be queued for the steal"
    mv2 = rb._move_one(g1, g2, mv.arrival_us + 2_000.0)
    assert mv2 is not None and mv2.kind == "steal" and mv2.dst == "gpu2"
    # the linger copy was harvested: gone from gpu0, travels with the task
    assert fabric.directory.get(0) is None
    assert g0.pool.used == 0
    g2.run(30_000_000.0, final=True)
    g0.run(30_000_000.0, final=True)
    rec = next(r for r in g2.records if r.task_id == 0)
    assert rec.finished_us is not None
    frags = [r for r in g0.records + g1.records + g2.records if r.task_id == 0]
    assert sum(r.iterations_done for r in frags) == 300


def test_steal_back_to_linger_holder_harvests_instead_of_retargeting():
    """A continuation re-routed back to the GPU that holds its lingering
    working set must not keep a directory entry (src == dst): the task
    re-owns its pages at admission, and a stale entry would keep feeding
    them to the holder's cluster_view as foreign runs on every switch."""
    topo = homogeneous(2, RTX5080, capacity_bytes=4 << 30, nvlink_gbps=NV)
    g0 = _serving_core("gpu0", req_id=0, output_tokens=300)
    g1 = _serving_core("gpu1", req_id=1, output_tokens=2)
    g1.admission = QueueAll()
    fabric = PeerPrefetchFabric(topo, [g0, g1])
    fabric.wire()
    rb = Rebalancer(topo, prefetch=fabric)
    rb.attach([g0, g1])
    g0.run(200_000.0, final=False)
    mv = rb._move_one(g0, g1, 200_000.0)
    assert mv is not None and mv.kind == "p2p"
    g1.run(mv.arrival_us + 1_000.0, final=False)
    assert g1.waiting
    mv2 = rb._move_one(g1, g0, mv.arrival_us + 2_000.0)
    assert mv2 is not None and mv2.kind == "steal" and mv2.dst == "gpu0"
    # harvested, not retargeted: no stale entry, no stale linger flag
    assert fabric.directory.get(0) is None
    assert 0 not in g0.lingering
    g0.run(30_000_000.0, final=True)
    frags = [r for r in g0.records + g1.records if r.task_id == 0]
    assert sum(r.iterations_done for r in frags) == 300
    assert any(r.finished_us is not None for r in frags)
    # nothing foreign left for the holder's cluster view
    assert fabric._make_cluster_view(g0)(g0.t) == []


def test_deadline_rejections_never_lose_requests_end_to_end():
    """With deadline admission + rebalancing on an NVLink fleet, every
    request ends finished or rejected — retries bounced during the terminal
    drain are re-drained, never silently dropped."""
    rep = simulate_cluster(
        _trace(rate=8.0, duration=2.0, output_mean=48),
        homogeneous(2, RTX5080, capacity_bytes=3 << 30, nvlink_gbps=NV),
        backend="msched", placement=Pin0(),
        admission_factory=lambda i: MSchedAdmission(
            headroom=0.9, max_wait_us=600_000.0
        ),
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=PAGE,
        rebalance_period_us=250_000.0, rebalance_threshold=0.3,
        drain_factor=12.0,
    )
    unresolved = [
        r for r in rep.merged.requests
        if r.finished_us is None and not r.rejected
    ]
    assert not unresolved, f"lost requests: {[r.task_id for r in unresolved]}"
    assert rep.stats.n_finished + rep.stats.n_rejected == rep.stats.n_requests
    assert rep.merged.hbm_used_pages == 0


def test_retry_budget_bounds_ping_pong():
    """A continuation every GPU rejects is eventually allowed to drop —
    after max_retries bounces, not infinitely."""
    topo = homogeneous(2, RTX5080, capacity_bytes=4 << 30)
    src = _serving_core("gpu0", req_id=0, output_tokens=300)
    dst = _serving_core("gpu1", req_id=1, output_tokens=2)
    rb = Rebalancer(topo, max_retries=2)
    rb.attach([src, dst])
    src.run(200_000.0, final=False)
    mv = rb._move_one(src, dst, 200_000.0)
    assert mv is not None
    # now *both* GPUs reject everything: the continuation bounces until the
    # retry budget runs out, then the rejection stands
    src.admission = RejectAll()
    dst.admission = RejectAll()
    for _ in range(6):
        dst.run(dst.t + 1_000_000.0, final=False)
        src.run(src.t + 1_000_000.0, final=False)
    retries = [e for e in rb.events if e.kind == "retry"]
    assert len(retries) == 2
    frags = [r for r in src.records + dst.records if r.task_id == 0]
    assert any(r.rejected for r in frags)
