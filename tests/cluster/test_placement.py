"""Placement policies against synthetic per-GPU load views."""
import pytest

from repro.cluster.placement import (
    LeastLoadedPlacement,
    MSchedPlacement,
    RoundRobinPlacement,
    make_placement,
)
from repro.core.hardware import A100_40G, RTX5080
from repro.core.hbm import HBMPool
from repro.core.scheduler import RoundRobinPolicy
from repro.core.simulator import SimState
from repro.core.workloads import TaskProgram, footprint_pages

PAGE = 4096


class _Prog(TaskProgram):
    """Finite program with an exact page footprint."""

    def __init__(self, task_id, pages):
        super().__init__(task_id, page_size=PAGE)
        self.space.malloc(pages * PAGE, "buf")

    def iteration(self, it):
        return []


class FakeCore:
    def __init__(
        self, name, capacity_pages, progs=(), platform=RTX5080,
        waiting_pages=0, quantum=5_000.0,
    ):
        self.name = name
        self._state = SimState(
            now=0.0,
            platform=platform,
            pool=HBMPool(capacity_pages),
            policy=RoundRobinPolicy(quantum),
            page_size=PAGE,
            active={p.task_id: p for p in progs},
            helpers={},
            waiting=0,
            waiting_pages=waiting_pages,
        )

    def state_view(self):
        return self._state


def test_round_robin_cycles():
    cores = [FakeCore(f"g{i}", 100) for i in range(3)]
    pol = RoundRobinPlacement()
    cand = _Prog(99, 10)
    assert [pol.place(cand, 0.0, cores) for _ in range(5)] == [0, 1, 2, 0, 1]


def test_least_loaded_counts_tasks_not_bytes():
    big = FakeCore("g0", 1000, [_Prog(0, 800)])  # one huge task
    small = FakeCore("g1", 1000, [_Prog(1, 10), _Prog(2, 10)])  # two tiny
    pol = LeastLoadedPlacement()
    # blind to memory: picks the GPU with fewer tasks even though it is the
    # memory-pressured one — the mispacking MSchedPlacement exists to fix
    assert pol.place(_Prog(99, 10), 0.0, [big, small]) == 0


def test_msched_placement_fits_by_predicted_demand():
    # helperless active tasks count at whole-footprint (conservative bound)
    pressured = FakeCore("g0", 1000, [_Prog(0, 800)])
    free = FakeCore("g1", 1000, [_Prog(1, 10), _Prog(2, 10)])
    pol = MSchedPlacement(headroom=0.9)
    cand = _Prog(99, 200)
    # g0: 0.9*1000 - 800 = 100 < 200 -> no fit; g1: 900 - 20 = 880 -> fit
    assert pol.place(cand, 0.0, [pressured, free]) == 1


def test_msched_placement_best_fit_is_tightest():
    a = FakeCore("g0", 1000, [_Prog(0, 100)])  # free 800
    b = FakeCore("g1", 1000, [_Prog(1, 600)])  # free 300
    pol = MSchedPlacement(headroom=0.9)
    # both fit a 200-page candidate; best-fit packs the tighter GPU (g1),
    # preserving g0's large contiguous headroom for big arrivals
    assert pol.place(_Prog(99, 200), 0.0, [a, b]) == 1


def test_msched_placement_counts_wait_queue():
    quiet = FakeCore("g0", 1000)
    backlogged = FakeCore("g1", 1000, waiting_pages=850)
    pol = MSchedPlacement(headroom=0.9)
    assert pol.place(_Prog(99, 200), 0.0, [quiet, backlogged]) == 0


def test_msched_placement_overload_is_capacity_relative():
    # nothing fits; the 2x-capacity GPU absorbs the spill
    small = FakeCore("g0", 1000, [_Prog(0, 900)], platform=A100_40G)
    big = FakeCore("g1", 2000, [_Prog(1, 1800)])
    pol = MSchedPlacement(headroom=0.9)
    cand = _Prog(99, 500)
    # g0: (900+500)/1000 = 1.4; g1: (1800+500)/2000 = 1.15 -> g1
    assert pol.place(cand, 0.0, [small, big]) == 1


def test_make_placement_registry():
    assert isinstance(make_placement("roundrobin"), RoundRobinPlacement)
    assert isinstance(make_placement("leastloaded"), LeastLoadedPlacement)
    assert isinstance(make_placement("msched"), MSchedPlacement)
    pol = MSchedPlacement(headroom=0.5)
    assert make_placement(pol) is pol
    with pytest.raises(KeyError):
        make_placement("nope")


def test_footprint_pages_rounds_up():
    p = _Prog(0, 3)
    assert footprint_pages(p, PAGE) == 3
    p.space.malloc(PAGE + 1, "ragged")  # 2 pages after round-up
    assert footprint_pages(p, PAGE) == 5
