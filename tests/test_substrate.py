"""Substrate tests: data determinism, checkpoint roundtrip + resharding,
fault-tolerant restart, elastic re-mesh, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.runtime.compression import compress_grads, init_error_feedback
from repro.runtime.train_loop import FailureInjector, TrainSupervisor

SMOKE = ShapeSpec("smoke", seq_len=32, global_batch=2, kind="train")


def test_pipeline_deterministic():
    p1 = TokenPipeline(DataConfig(64, 4, 1000, seed=7))
    p2 = TokenPipeline(DataConfig(64, 4, 1000, seed=7))
    for step in (0, 5, 123):
        b1, b2 = p1.batch(step), p2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch(0)["tokens"], p1.batch(1)["tokens"])
    # labels are next-token shifted
    full1 = p1.batch(3)
    np.testing.assert_array_equal(full1["tokens"][:, 1:], full1["labels"][:, :-1])


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2, 2), jnp.bfloat16), "d": jnp.int32(7)},
    }
    save(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    out = restore(str(tmp_path), 5, jax.eval_shape(lambda: tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_async(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros((4,), jnp.float32)}
    for s in (1, 2, 3, 4):
        ck.save_async(s, jax.tree.map(lambda x: x + s, tree))
    ck.wait()
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(tmp_path) if d.startswith("step_")
    )
    assert steps == [3, 4]


def test_train_restart_resumes_identically(tmp_path):
    cfg = get_config("qwen3-1.7b").reduced()
    # uninterrupted baseline
    sup = TrainSupervisor(cfg, SMOKE, str(tmp_path / "a"), ckpt_every=4)
    base = sup.run(total_steps=8)
    # interrupted at step 6 -> restart from step-4 checkpoint
    sup2 = TrainSupervisor(cfg, SMOKE, str(tmp_path / "b"), ckpt_every=4)
    rep = sup2.run(total_steps=8, injector=FailureInjector(fail_at=[6]))
    assert rep.restarts == 1
    assert rep.final_step == 8
    # the post-restart trajectory matches the uninterrupted run
    np.testing.assert_allclose(
        base.losses[-2:], rep.losses[-2:], rtol=1e-5, atol=1e-5
    )


def test_gradient_compression_roundtrip():
    cfg = get_config("llama3.2-3b").reduced()
    rng = jax.random.PRNGKey(0)
    grads = {
        "w": jax.random.normal(rng, (64, 64), jnp.float32) * 1e-3,
        "b": jax.random.normal(rng, (64,), jnp.float32) * 1e-3,
    }
    err = init_error_feedback(grads)
    deq, err, stats = compress_grads(grads, err)
    assert stats["compression_ratio"] > 3.0
    for g, d in zip(jax.tree.leaves(grads), jax.tree.leaves(deq)):
        rel = np.abs(np.asarray(g) - np.asarray(d)).max() / (
            np.abs(np.asarray(g)).max() + 1e-12
        )
        assert rel < 0.02
    # error feedback: accumulated error is bounded by one quantization step
    for g, e in zip(jax.tree.leaves(grads), jax.tree.leaves(err)):
        assert np.abs(np.asarray(e)).max() <= np.abs(np.asarray(g)).max() / 64


def test_error_feedback_reduces_bias():
    """Over repeated steps with constant gradient, error feedback makes the
    *mean* applied gradient converge to the true one."""
    g = {"w": jnp.full((32,), 3.3e-4, jnp.float32)}
    err = init_error_feedback(g)
    applied = []
    for _ in range(50):
        d, err, _ = compress_grads(g, err)
        applied.append(np.asarray(d["w"]))
    mean_applied = np.mean(applied, axis=0)
    np.testing.assert_allclose(mean_applied, 3.3e-4, rtol=0.02)
