"""Multi-device tests run in subprocesses (8 fake host devices) so the main
pytest process keeps its single real CPU device."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, timeout=480) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
        cwd=REPO,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_sharded_train_step_runs():
    """Real sharded execution on 8 devices: loss decreases over steps."""
    out = run_py("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.configs.base import ShapeSpec
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import make_train_state, make_train_step
        from repro.sharding.specs import param_shardings, opt_state_shardings
        from repro.sharding.act import use_activation_mesh
        from repro.data.pipeline import pipeline_for

        cfg = get_config("qwen3-1.7b").reduced()
        mesh = make_mesh((2, 4), ("data", "model"))
        shape = ShapeSpec("t", 64, 4, "train")
        state = make_train_state(cfg, jax.random.PRNGKey(0))
        pspecs = param_shardings(cfg, state["params"], mesh)
        ospecs = opt_state_shardings(cfg, state["opt"], pspecs, mesh)
        sspecs = {"params": pspecs, "opt": ospecs, "step": NamedSharding(mesh, P())}
        state = jax.device_put(state, sspecs)
        pipe = pipeline_for(cfg, shape)
        with use_activation_mesh(mesh):
            step = jax.jit(make_train_step(cfg), donate_argnums=(0,))
            losses = []
            for i in range(8):
                batch = {k: jnp.asarray(v) for k, v in pipe.batch(i).items()}
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
        print("LOSSES", losses[0], losses[-1])
        assert losses[-1] < losses[0], losses
    """)
    assert "LOSSES" in out


def test_elastic_remesh_resumes():
    """Checkpoint on a (2,4) mesh, restore + continue on (1,2) with fewer
    devices — the elastic scaling path."""
    out = run_py("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.configs.base import ShapeSpec
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import make_train_state, make_train_step
        from repro.sharding.specs import param_shardings, opt_state_shardings
        from repro.sharding.act import use_activation_mesh
        from repro.data.pipeline import pipeline_for
        from repro.checkpointing.checkpoint import save, restore

        cfg = get_config("llama3.2-3b").reduced()
        shape = ShapeSpec("t", 64, 4, "train")
        pipe = pipeline_for(cfg, shape)
        ckpt = tempfile.mkdtemp()

        def shardings(mesh, state_shape):
            pspecs = param_shardings(cfg, state_shape["params"], mesh)
            ospecs = opt_state_shardings(cfg, state_shape["opt"], pspecs, mesh)
            return {"params": pspecs, "opt": ospecs, "step": NamedSharding(mesh, P())}

        # phase 1: 8 devices
        mesh1 = make_mesh((2, 4), ("data", "model"))
        state = make_train_state(cfg, jax.random.PRNGKey(0))
        state = jax.device_put(state, shardings(mesh1, state))
        with use_activation_mesh(mesh1):
            step = jax.jit(make_train_step(cfg), donate_argnums=(0,))
            for i in range(3):
                state, m = step(state, {k: jnp.asarray(v) for k, v in pipe.batch(i).items()})
        save(ckpt, 3, state)
        l3 = float(m["loss"])

        # phase 2: "node loss" -> re-mesh to 2 devices, restore, continue
        mesh2 = make_mesh((1, 2), ("data", "model"))
        abs_state = jax.eval_shape(lambda: make_train_state(cfg, jax.random.PRNGKey(0)))
        sspecs2 = shardings(mesh2, abs_state)
        target = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), abs_state, sspecs2)
        state2 = restore(ckpt, 3, target)
        with use_activation_mesh(mesh2):
            step2 = jax.jit(make_train_step(cfg), donate_argnums=(0,))
            state2, m2 = step2(state2, {k: jnp.asarray(v) for k, v in pipe.batch(3).items()})
        print("RESUMED", l3, float(m2["loss"]))
        assert np.isfinite(float(m2["loss"]))
        assert int(jax.device_get(state2["step"])) == 4
    """)
    assert "RESUMED" in out


def test_dryrun_cell_smoke():
    """The dry-run machinery end-to-end on a reduced mesh/config."""
    out = run_py("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.launch import dryrun
        # monkeypatch the production mesh to the 8-device test mesh
        import repro.launch.mesh as mesh_lib
        dryrun.make_production_mesh = lambda multi_pod=False: mesh_lib.make_mesh(
            (2, 2, 2) if multi_pod else (2, 4),
            ("pod", "data", "model") if multi_pod else ("data", "model"))
        for mp in (False, True):
            rec = dryrun.run_cell("qwen3-1.7b", "train_4k", mp)
            assert rec["status"] == "ok", rec.get("error")
            assert rec["hlo_costs"]["dot_flops"] > 0
            assert sum(rec["hlo_costs"]["collective_bytes"].values()) > 0
        print("DRYRUN_OK")
    """, timeout=560)
    assert "DRYRUN_OK" in out


def test_multipod_gradient_reduction_over_pod_axis():
    """Multi-pod mesh: gradients must reduce over the pod axis (DCN)."""
    out = run_py("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh, dp_axes
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        assert dp_axes(mesh) == ("pod", "data")
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32, sharding=NamedSharding(mesh, P(None, "model")))
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32, sharding=NamedSharding(mesh, P(("pod", "data"), None)))
        def loss(w, x):
            return ((x @ w) ** 2).mean()
        c = jax.jit(jax.grad(loss)).lower(w, x).compile()
        txt = c.as_text()
        assert "all-reduce" in txt
        print("PODOK")
    """)
    assert "PODOK" in out
