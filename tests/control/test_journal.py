"""The write-ahead decision journal: closed kind set, append ordering,
FIFO hold/release matching, and the primitive-only JSON export."""
import pytest

from repro.control import JOURNAL_KINDS, DecisionJournal


def test_unknown_kind_raises():
    j = DecisionJournal()
    with pytest.raises(ValueError):
        j.append("meteor_strike", 0.0, 1)


def test_seq_is_global_append_order():
    j = DecisionJournal()
    for k in ("submit", "place", "admit", "finish"):
        j.append(k, 0.0, 1)
    assert [r.seq for r in j] == [0, 1, 2, 3]
    assert len(j) == 4


def test_unreleased_fifo_matching():
    j = DecisionJournal()
    j.append("hold", 0.0, 1, ev="first")
    j.append("hold", 1.0, 1, ev="second")
    j.append("strand", 2.0, 2)
    j.append("requeue", 3.0, 3)
    # one release of task 1's holds pops the OLDEST (FIFO)
    j.append("release", 4.0, 1, of="hold")
    # a release whose kind does not match leaves the queue alone
    j.append("release", 5.0, 2, of="hold")
    open_recs = j.unreleased()
    assert [(r.kind, r.task_id) for r in open_recs] == [
        ("hold", 1),
        ("strand", 2),
        ("requeue", 3),
    ]
    assert open_recs[0].payload["ev"] == "second"
    # seq-sorted: replay re-parks in decision order
    assert [r.seq for r in open_recs] == sorted(r.seq for r in open_recs)


def test_release_without_hold_is_ignored():
    j = DecisionJournal()
    j.append("release", 0.0, 9, of="strand")
    assert j.unreleased() == []


def test_to_json_drops_reference_payloads():
    j = DecisionJournal()

    class Prog:  # a live sim object that must not leak into the dump
        pass

    j.append("strand", 5.0, 2, prog=Prog(), completed=7, origin="gpu0")
    (doc,) = j.to_json()
    assert doc == {
        "seq": 0,
        "time_us": 5.0,
        "kind": "strand",
        "task_id": 2,
        "completed": 7,
        "origin": "gpu0",
    }


def test_kind_set_is_closed_and_documented():
    # every kind used across the integration sites is in the set
    for k in (
        "submit", "place", "admit", "finish", "reject", "shed", "cancel",
        "migrate", "reroute", "checkpoint", "recovery", "preempt", "fail",
        "hold", "strand", "requeue", "release", "crash", "recover",
    ):
        assert k in JOURNAL_KINDS
    assert len(JOURNAL_KINDS) == 19
