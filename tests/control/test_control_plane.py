"""Control-plane integration pins: the zero-impact observer guarantee,
coordinator crash + journal replay vs cold restart, replay idempotence,
deadline enforcement (preempt -> backoff -> shed), the operator
submit/cancel/status surface, and wiring validation."""
import pytest

from repro.cluster import (
    FaultEvent,
    FaultInjector,
    homogeneous,
    simulate_cluster,
)
from repro.control import (
    CANCELLED,
    ControlPlane,
    DeadlineSpec,
)
from repro.core.hardware import NVLINK_A100_GBPS, RTX5080
from repro.core.scheduler import RoundRobinPolicy
from repro.serving import MSchedAdmission, Request, Trace, poisson_trace
from repro.telemetry import Telemetry

ARCH = "qwen3-1.7b"
PAGE = 1 << 20
NV = NVLINK_A100_GBPS


def _trace(rate=6.0, duration=1.2, seed=5, output_mean=120, rt_fraction=0.0):
    return poisson_trace(
        rate, duration, seed=seed, tenants=(ARCH,), prompt_mean=64,
        output_mean=output_mean, max_output=2 * output_mean,
        rt_fraction=rt_fraction,
    )


def _topo(n=2, cap=4 << 30):
    return homogeneous(n, RTX5080, capacity_bytes=cap, nvlink_gbps=NV)


def _run(trace, topo, *, backend="msched", faults=None, control=None,
         telemetry=None, **kw):
    quantum = 2_000.0 if backend == "um" else 350_000.0
    args = dict(
        backend=backend, placement="leastloaded",
        policy_factory=lambda i: RoundRobinPolicy(quantum),
        page_size=PAGE, drain_factor=20.0,
    )
    if backend == "msched":
        args["admission_factory"] = lambda i: MSchedAdmission(headroom=0.9)
    args.update(kw)
    return simulate_cluster(
        trace, topo, faults=faults, control=control, telemetry=telemetry,
        **args
    )


def _rec_tuple(r):
    return (
        r.task_id, r.arrival_us, r.admitted_us, r.first_iter_us,
        r.finished_us, r.iterations_done, r.total_iterations, r.rejected,
    )


def _crash_cycle():
    """A coordinator outage bracketing a GPU fail/recover: the victims
    strand in coordinator queues until the coordinator returns."""
    return [
        FaultEvent(300_000.0, "coordinator_crash"),
        FaultEvent(400_000.0, "gpu_fail", gpu="gpu0"),
        FaultEvent(600_000.0, "gpu_recover", gpu="gpu0"),
        FaultEvent(800_000.0, "coordinator_recover"),
    ]


# --------------------------------------------------------------------------
# the pure-observer guarantee
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["um", "msched", "ideal", "suv"])
def test_zero_fault_control_is_bit_for_bit(backend):
    """A control plane attached to a fault-free run (no deadline
    enforcement, no scheduled ops) only journals: the report is identical
    to the plain run in every field except the journal length."""
    plain = _run(_trace(), _topo(), backend=backend)
    cp = ControlPlane()
    ctl = _run(
        _trace(), _topo(), backend=backend,
        faults=FaultInjector.none(), control=cp,
    )
    a, b = plain.to_row(), ctl.to_row()
    assert a.pop("journal_len") == 0 and b.pop("journal_len") > 0
    assert a == b
    assert [_rec_tuple(r) for r in plain.merged.requests] == [
        _rec_tuple(r) for r in ctl.merged.requests
    ]
    # and the journal saw the full story of every request
    assert cp.lifecycle.count("FINISHED") == ctl.stats.n_finished


def test_generous_deadline_monitoring_is_bit_for_bit():
    """Deadline monitoring with deadlines nothing can miss never fires:
    still bit-for-bit the plain run."""
    plain = _run(_trace(rt_fraction=0.3), _topo())
    cp = ControlPlane(
        deadlines=DeadlineSpec(rt_ttft_us=9e9, rt_latency_us=9e9),
        deadline_period_us=50_000.0,
    )
    ctl = _run(
        _trace(rt_fraction=0.3), _topo(),
        faults=FaultInjector.none(), control=cp,
    )
    a, b = plain.to_row(), ctl.to_row()
    a.pop("journal_len"), b.pop("journal_len")
    assert a == b
    assert ctl.preemptions == 0 and ctl.deadline_misses == 0


# --------------------------------------------------------------------------
# coordinator crash: journal replay vs cold restart
# --------------------------------------------------------------------------


def test_coordinator_faults_require_control():
    with pytest.raises(ValueError, match="control plane"):
        _run(_trace(), _topo(), faults=FaultInjector(_crash_cycle()))


def test_crash_journal_replay_preserves_completions():
    """The acceptance pin: a coordinator crash bracketing a GPU failure,
    recovered by journal replay, completes exactly the tasks the crash-free
    run completes — and the double-replay check proves replay idempotent
    at every recovery."""
    base = _run(
        _trace(), _topo(),
        faults=FaultInjector(_crash_cycle()[1:3]),  # gpu fault only
        recovery="auto", checkpoint_period_us=250_000.0, audit=True,
    )
    cp = ControlPlane(recovery="journal", replay_check=True)
    rep = _run(
        _trace(), _topo(),
        faults=FaultInjector(_crash_cycle()),
        recovery="auto", checkpoint_period_us=250_000.0,
        control=cp, audit=True,
    )
    survivors = {
        r.task_id for r in base.merged.requests if r.finished_us is not None
    }
    replayed = {
        r.task_id for r in rep.merged.requests if r.finished_us is not None
    }
    assert replayed == survivors
    assert rep.lost_requests == 0
    assert rep.coordinator_crashes == 1 and rep.journal_replays == 1
    assert rep.journal_len == len(cp.journal) > 0


def test_cold_restart_forfeits_stranded_work():
    """Same timeline, cold coordinator restart: down-time strandings are
    dropped at recovery — accounted as lost, never silent."""
    cp = ControlPlane(recovery="cold")
    rep = _run(
        _trace(), _topo(),
        faults=FaultInjector(_crash_cycle()),
        recovery="auto", checkpoint_period_us=250_000.0,
        control=cp, audit=True,
    )
    assert rep.lost_requests > 0
    assert rep.journal_replays == 0
    # every request still has exactly one resolved record
    unresolved = [
        r for r in rep.merged.requests
        if r.finished_us is None and not r.rejected
    ]
    assert not unresolved


def test_terminal_coordinator_outage_accounts_everything():
    """The coordinator dies and never comes back: backlog arrivals and
    parked work are accounted as lost at drain."""
    tr = _trace(rate=8.0, duration=0.8, output_mean=60)
    cp = ControlPlane(recovery="journal")
    rep = _run(
        tr, _topo(),
        faults=FaultInjector([
            FaultEvent(200_000.0, "coordinator_crash"),
            FaultEvent(250_000.0, "gpu_fail", gpu="gpu0"),
            FaultEvent(280_000.0, "gpu_fail", gpu="gpu1"),
        ]),
        recovery="auto", control=cp, audit=True,
    )
    assert rep.lost_requests > 0
    assert {r.task_id for r in rep.merged.requests} == {
        r.req_id for r in tr
    }
    unresolved = [
        r for r in rep.merged.requests
        if r.finished_us is None and not r.rejected
    ]
    assert not unresolved


def test_crash_telemetry_events():
    tel = Telemetry()
    cp = ControlPlane(recovery="journal")
    _run(
        _trace(), _topo(),
        faults=FaultInjector(_crash_cycle()),
        recovery="auto", control=cp, audit=True, telemetry=tel,
    )
    names = {ev.name for ev in tel.events}
    assert {"coordinator_crash", "coordinator_recover", "journal_replay"} \
        <= names


# --------------------------------------------------------------------------
# deadline enforcement
# --------------------------------------------------------------------------


def _overload_run(control):
    return _run(
        _trace(rate=14.0, duration=1.5, seed=9, output_mean=300,
               rt_fraction=0.25),
        _topo(n=1, cap=2 << 30),
        faults=FaultInjector.none(), control=control,
        placement="roundrobin", audit=True,
    )


def test_deadline_preemption_fires_under_overload():
    cp = ControlPlane(
        deadlines=DeadlineSpec(rt_ttft_us=100_000.0, rt_latency_us=500_000.0),
        deadline_period_us=40_000.0,
    )
    rep = _overload_run(cp)
    assert rep.preemptions > 0
    assert rep.deadline_misses > 0  # finalize scored the misses
    assert cp.rt_requests > 0
    # preempted BE victims carry the eject/re-inject trail and still finish
    preempted = [
        r for r in rep.merged.requests if "preempted_us" in r.meta
    ]
    assert preempted
    assert rep.stats.n_finished == rep.stats.n_requests


def test_escalation_sheds_past_max_preemptions():
    """One perpetually-at-risk RT task and exactly one BE task: the monitor
    must re-pick the same victim, and the pick past ``max_preemptions``
    escalates the preemption to a journaled shed."""
    tr = Trace([
        Request(0, ARCH, 0.0, prompt_tokens=64, output_tokens=800,
                slo_class="rt"),
        Request(1, ARCH, 10_000.0, prompt_tokens=64, output_tokens=800,
                slo_class="be"),
    ])
    cp = ControlPlane(
        deadlines=DeadlineSpec(
            rt_ttft_us=100_000.0, rt_latency_us=1_000_000.0,
        ),
        deadline_period_us=40_000.0,
        max_preemptions=1,  # the second pick of the same victim escalates
    )
    rep = _run(
        # 12 GiB so both model instances are resident concurrently: the
        # victim must be *running* to be picked, twice
        tr, _topo(n=1, cap=12 << 30), faults=FaultInjector.none(),
        control=cp, placement="roundrobin", audit=True,
        sim_us=6_000_000.0,
    )
    assert rep.preemptions == 1 and rep.deadline_sheds == 1
    (shed,) = [
        r for r in rep.merged.requests if "deadline_shed_us" in r.meta
    ]
    assert shed.task_id == 1 and shed.rejected
    assert "preempted_us" in shed.meta  # first rung of the ladder fired too
    assert cp.lifecycle.count("SHED") == 1
    # the RT task itself is never a victim
    (rt,) = [r for r in rep.merged.requests if r.task_id == 0]
    assert not rt.rejected


# --------------------------------------------------------------------------
# operator surface + wiring validation
# --------------------------------------------------------------------------


def test_cancel_api_resolves_the_task():
    cp = ControlPlane()
    cp.cancel(1, 500_000.0)  # task 1 runs ~388-740ms on this seed
    rep = _run(
        _trace(), _topo(), faults=FaultInjector.none(), control=cp,
    )
    assert cp.status(1) == CANCELLED
    (rec,) = [r for r in rep.merged.requests if r.task_id == 1]
    assert rec.rejected and "cancelled_us" in rec.meta
    # cancelling an unknown/terminal task later is a safe no-op
    assert cp.lifecycle.count("CANCELLED") == 1


def test_attach_reuse_and_bad_mode_raise():
    with pytest.raises(ValueError):
        ControlPlane(recovery="warmish")
    cp = ControlPlane()
    _run(_trace(rate=2.0, duration=0.4), _topo(),
         faults=FaultInjector.none(), control=cp)
    with pytest.raises(ValueError):
        _run(_trace(rate=2.0, duration=0.4), _topo(),
             faults=FaultInjector.none(), control=cp)
