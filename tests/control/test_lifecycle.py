"""Task lifecycle state machine: legal/illegal edges, terminal absorption,
the journal-kind -> lifecycle-event mapping, and the cold-restart `assume`
escape hatch."""
import pytest

from repro.control import (
    ADMITTED,
    CANCELLED,
    CHECKPOINTED,
    FAILED,
    FINISHED,
    LEGAL_EDGES,
    MIGRATING,
    RUNNING,
    SHED,
    SUBMITTED,
    TASK_STATES,
    TERMINAL_STATES,
    LifecycleError,
    TaskLifecycle,
    apply_event,
)
from repro.core.invariants import InvariantViolation


def test_edge_table_is_closed_over_known_states():
    assert set(LEGAL_EDGES) == set(TASK_STATES)
    for dsts in LEGAL_EDGES.values():
        assert dsts <= set(TASK_STATES)
    for t in TERMINAL_STATES:
        assert not LEGAL_EDGES[t], "terminal states have no outgoing edges"


def test_happy_path_and_status():
    lc = TaskLifecycle()
    lc.submit(7, 0.0)
    assert lc.state(7) == SUBMITTED
    lc.transition(7, ADMITTED, 1.0)
    lc.transition(7, RUNNING, 2.0)
    lc.transition(7, FINISHED, 3.0)
    assert lc.state(7) == FINISHED
    assert lc.since(7) == 3.0


def test_illegal_edges_raise_lifecycle_error():
    lc = TaskLifecycle()
    lc.submit(1, 0.0)
    # SUBMITTED -> RUNNING skips admission
    with pytest.raises(LifecycleError):
        lc.transition(1, RUNNING, 1.0)
    # LifecycleError is an InvariantViolation (and hence AssertionError)
    with pytest.raises(InvariantViolation):
        lc.transition(1, FINISHED, 1.0)
    lc.transition(1, ADMITTED, 1.0)
    lc.transition(1, RUNNING, 2.0)
    lc.transition(1, FINISHED, 3.0)
    # terminal states absorb: nothing leaves FINISHED
    for dst in (RUNNING, CANCELLED, SHED):
        with pytest.raises(LifecycleError):
            lc.transition(1, dst, 4.0)


def test_duplicate_submit_and_unknown_task_raise():
    lc = TaskLifecycle()
    lc.submit(1, 0.0)
    with pytest.raises(LifecycleError):
        lc.submit(1, 1.0)
    with pytest.raises(LifecycleError):
        lc.transition(99, ADMITTED, 1.0)
    assert lc.state(99) is None


def test_recovery_cycle_edges():
    """The fault path: RUNNING -> FAILED -> ADMITTED -> RUNNING again."""
    lc = TaskLifecycle()
    lc.submit(3, 0.0)
    lc.transition(3, ADMITTED, 1.0)
    lc.transition(3, RUNNING, 2.0)
    lc.transition(3, FAILED, 3.0)
    lc.transition(3, ADMITTED, 4.0)
    lc.transition(3, RUNNING, 5.0)
    lc.transition(3, MIGRATING, 6.0)
    lc.transition(3, RUNNING, 7.0)
    lc.transition(3, CHECKPOINTED, 8.0)
    lc.transition(3, RUNNING, 9.0)
    lc.transition(3, FINISHED, 10.0)


def test_assume_skips_validation_for_cold_restart():
    lc = TaskLifecycle()
    lc.assume(5, RUNNING, 1.0)  # never submitted — amnesiac rebuild
    assert lc.state(5) == RUNNING
    lc.transition(5, FINISHED, 2.0)


def test_apply_event_maps_journal_kinds():
    lc = TaskLifecycle()
    apply_event(lc, "submit", 1, 0.0)
    assert lc.state(1) == SUBMITTED
    apply_event(lc, "place", 1, 1.0)
    assert lc.state(1) == ADMITTED
    apply_event(lc, "admit", 1, 2.0)
    assert lc.state(1) == RUNNING
    # checkpoint is a validated double-step through CHECKPOINTED
    apply_event(lc, "checkpoint", 1, 3.0)
    assert lc.state(1) == RUNNING
    apply_event(lc, "preempt", 1, 4.0)
    assert lc.state(1) == MIGRATING
    apply_event(lc, "place", 1, 5.0)
    apply_event(lc, "admit", 1, 6.0)
    apply_event(lc, "fail", 1, 7.0)
    assert lc.state(1) == FAILED
    apply_event(lc, "recovery", 1, 8.0)
    assert lc.state(1) == ADMITTED
    # reroute is a validated no-op: legal while ADMITTED
    apply_event(lc, "reroute", 1, 9.0)
    assert lc.state(1) == ADMITTED
    apply_event(lc, "admit", 1, 10.0)
    apply_event(lc, "finish", 1, 11.0)
    assert lc.state(1) == FINISHED


def test_apply_event_reject_shed_cancel():
    lc = TaskLifecycle()
    apply_event(lc, "submit", 1, 0.0)
    apply_event(lc, "place", 1, 1.0)
    apply_event(lc, "reject", 1, 2.0)
    assert lc.state(1) == SHED
    apply_event(lc, "submit", 2, 0.0)
    apply_event(lc, "cancel", 2, 1.0)
    assert lc.state(2) == CANCELLED


def test_apply_event_validates_inputs():
    lc = TaskLifecycle()
    with pytest.raises(LifecycleError):
        apply_event(lc, "admit", None, 0.0)  # lifecycle kind needs a task
    with pytest.raises(LifecycleError):
        apply_event(lc, "meteor_strike", 1, 0.0)
    apply_event(lc, "submit", 1, 0.0)
    with pytest.raises(LifecycleError):
        apply_event(lc, "reroute", 1, 1.0)  # only ADMITTED/MIGRATING reroute
